//! Bounded, closable, *resizable* lock-free SPSC queue — zero-contention
//! hot path.
//!
//! Implementation: a segmented linked list of fixed-size blocks (producer
//! appends, consumer frees), bounded by an **atomic capacity** rather than
//! a fixed ring size. That makes the paper's §III resize trick — "given a
//! full out-bound queue, resizing the queue provides a brief window over
//! which to observe fully non-blocking behavior" — a single atomic store,
//! with no data movement and no locking of either end.
//!
//! # Synchronization protocol
//!
//! Exactly one producer thread, one consumer thread, any number of monitor
//! threads touching only counters/capacity. Each end owns a **monotonic
//! index** (living in [`QueueCounters`], so the index doubles as the
//! paper's `tc`/total instrumentation at zero extra cost) and keeps a
//! *cached snapshot* of the peer's index, touching the peer's cache line
//! only when the cache says full/empty:
//!
//! * **producer** owns `tail`: checks `tail − head_cache < capacity`
//!   (reloading `head_cache` only on apparent full), writes the slot,
//!   links new blocks, then publishes with a single
//!   `tail.store(tail + 1, Release)` — a plain store, **no RMW and no
//!   peer-line read** in the common case;
//! * **consumer** owns `head`: on `head == tail_cache` reloads the tail
//!   with `Acquire` (which makes the slot contents and `next` pointers
//!   visible), reads the slot, then retires with
//!   `head.store(head + 1, Release)`;
//! * **occupancy** is never stored anywhere: `len() = tail − head`,
//!   computed on demand (head loaded first, so the difference can't go
//!   negative);
//! * **close**: the closer sets `closed` (Release) after the final
//!   publish; the consumer treats `closed && head == tail` as
//!   end-of-stream, re-reading `tail` *after* observing `closed` so the
//!   verdict is final. (A third party — e.g. the elastic control plane —
//!   may also close; the producer then gets the item back via
//!   `PushError::Closed`.)
//!
//! # Blocking & backoff
//!
//! The blocking `push`/`pop` escalate **spin → yield → park**: a bounded
//! spin for sub-microsecond waits, a bounded yield phase, then the thread
//! parks and is woken by the peer's next publish (the peer checks a
//! `parked` flag — one Relaxed load of a normally-cold line — and only
//! then takes the wake slow path). Parking uses `park_timeout` with an
//! escalating bound as a safety net: the parked flag is raised *before*
//! the final state re-check, which with the SeqCst flag operations makes
//! a lost wakeup vanishingly rare, and the timeout bounds the stall if it
//! ever happens. A parked kernel burns **zero** CPU, so the monitor no
//! longer misreads a blocked kernel as busy. Blocked time is accumulated
//! as a **duration** (ns) into [`QueueCounters`] while the wait is in
//! progress, so a concurrent monitor sample observes the block as it
//! happens (§IV validity), with sub-period micro-blocks distinguishable
//! from fully-blocked periods.
//!
//! # Batched transfer
//!
//! [`SpscQueue::try_push_iter`] / [`SpscQueue::push_iter`] /
//! [`SpscQueue::pop_batch`] move runs of items with **one Release publish
//! per batch** instead of per item, amortizing the only cross-core store
//! on the path.

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicBool, AtomicPtr, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crossbeam_utils::CachePadded;

use super::counters::QueueCounters;

/// Items per block. Amortizes allocation; keeps resize latency at zero.
const BLOCK: usize = 256;

/// Pure-spin passes before a blocked end starts yielding.
const SPIN_PASSES: u32 = 64;
/// Yield passes before a blocked end parks.
const YIELD_PASSES: u32 = 64;
/// First park timeout (safety net against a lost wakeup), ns.
const PARK_MIN_NS: u64 = 100_000;
/// Park timeout ceiling, ns.
const PARK_MAX_NS: u64 = 2_000_000;

struct Block<T> {
    slots: [UnsafeCell<MaybeUninit<T>>; BLOCK],
    next: AtomicPtr<Block<T>>,
}

impl<T> Block<T> {
    fn alloc() -> *mut Block<T> {
        // MaybeUninit slots need no initialization beyond zeroed metadata.
        let b: Box<Block<T>> = Box::new(Block {
            // SAFETY: an array of MaybeUninit is validly uninitialized.
            slots: unsafe { MaybeUninit::uninit().assume_init() },
            next: AtomicPtr::new(std::ptr::null_mut()),
        });
        Box::into_raw(b)
    }
}

/// Producer-private state: write cursor + the local/cached indices.
struct ProdState<T> {
    block: *mut Block<T>,
    idx: usize,
    /// Local mirror of the published tail index (we are its only writer).
    tail: u64,
    /// Last observed consumer head; reloaded only on apparent full.
    head_cache: u64,
}

/// Consumer-private state: read cursor + the local/cached indices.
struct ConsState<T> {
    block: *mut Block<T>,
    idx: usize,
    /// Local mirror of the published head index (we are its only writer).
    head: u64,
    /// Last observed producer tail; reloaded only on apparent empty.
    tail_cache: u64,
}

/// One end's park/wake handshake. The `parked` flag lives on its own
/// cache line (via the queue's `CachePadded` wrapper) and is almost
/// always `false`, so the peer's per-publish check is a cheap
/// read-mostly load.
struct Waiter {
    parked: AtomicBool,
    thread: Mutex<Option<std::thread::Thread>>,
}

impl Waiter {
    fn new() -> Self {
        Waiter { parked: AtomicBool::new(false), thread: Mutex::new(None) }
    }

    /// Publish intent to park. Call *before* the final state re-check so
    /// the peer's publish→flag-check cannot slip between check and park
    /// unnoticed (SeqCst on the flag narrows the classic store-buffer
    /// race; the park timeout bounds whatever remains).
    fn prepare(&self) {
        *self.thread.lock().unwrap_or_else(|e| e.into_inner()) =
            Some(std::thread::current());
        self.parked.store(true, Ordering::SeqCst);
    }

    /// Withdraw the intent (after waking or on exit paths).
    fn cancel(&self) {
        self.parked.store(false, Ordering::SeqCst);
    }

    /// Peer-side wake. The fast path is a single Relaxed load.
    #[inline]
    fn wake(&self) {
        if self.parked.load(Ordering::Relaxed) {
            self.wake_slow();
        }
    }

    #[cold]
    fn wake_slow(&self) {
        if self.parked.swap(false, Ordering::SeqCst) {
            // A panicked peer may have poisoned the mutex mid-park; the
            // thread handle inside is still perfectly usable.
            if let Some(t) = self.thread.lock().unwrap_or_else(|e| e.into_inner()).take() {
                t.unpark();
            }
        }
    }
}

/// Blocked-time bookkeeping for one blocking wait: flushes the elapsed
/// slice into the counters and clears the in-progress wait marker on
/// *every* exit path — normal returns and unwinds alike — via `Drop`,
/// with [`WaitGuard::flush`] as the mid-wait checkpoint. One mechanism
/// instead of a hand-copied epilogue per exit arm.
struct WaitGuard<'a> {
    counters: &'a QueueCounters,
    time: crate::timing::TimeRef,
    last_flush: u64,
    write_side: bool,
}

impl<'a> WaitGuard<'a> {
    fn new(counters: &'a QueueCounters, write_side: bool) -> Self {
        let time = crate::timing::TimeRef::new();
        let now = time.now_ns();
        // Mark the wait in progress so samples taken while this end is
        // parked (unable to flush) still see the blocked time.
        if write_side {
            counters.mark_write_waiting(now.max(1));
        } else {
            counters.mark_read_waiting(now.max(1));
        }
        WaitGuard { counters, time, last_flush: now, write_side }
    }

    /// Mid-wait checkpoint: flush the elapsed slice, advance the marker.
    /// Flush first, then marker — a racing sample at worst double-counts
    /// the just-flushed slice (conservatively blocked), never misses one.
    fn flush(&mut self) {
        let now = self.time.now_ns();
        let span = now.saturating_sub(self.last_flush);
        self.last_flush = now;
        if self.write_side {
            self.counters.note_write_blocked(span);
            self.counters.mark_write_waiting(now.max(1));
        } else {
            self.counters.note_read_blocked(span);
            self.counters.mark_read_waiting(now.max(1));
        }
    }
}

impl Drop for WaitGuard<'_> {
    fn drop(&mut self) {
        let span = self.time.now_ns().saturating_sub(self.last_flush);
        if self.write_side {
            self.counters.note_write_blocked(span);
            self.counters.mark_write_waiting(0);
        } else {
            self.counters.note_read_blocked(span);
            self.counters.mark_read_waiting(0);
        }
    }
}

/// The queue. See module docs for the protocol.
pub struct SpscQueue<T> {
    /// Producer-private cursor and index cache.
    prod: CachePadded<UnsafeCell<ProdState<T>>>,
    /// Consumer-private cursor and index cache.
    cons: CachePadded<UnsafeCell<ConsState<T>>>,
    /// Admission bound — atomically adjustable (§III resize).
    capacity: AtomicUsize,
    /// Stream closed (producer- or control-plane-set).
    closed: AtomicBool,
    /// Stream poisoned: closed *because a peer died* (kernel panic,
    /// deadline abort) rather than because the producer finished. The
    /// flag refines `closed` — every poisoned queue is also closed, so
    /// blocked ends unpark through the ordinary close protocol — and
    /// lets the scheduler audit items stranded in the queue as *lost*
    /// instead of merely undelivered.
    poisoned: AtomicBool,
    /// Producer's park state (woken by consumer pops).
    prod_waiter: CachePadded<Waiter>,
    /// Consumer's park state (woken by producer pushes and by close).
    cons_waiter: CachePadded<Waiter>,
    /// Instrumentation block; owns the published head/tail indices.
    counters: QueueCounters,
}

// SAFETY: the SPSC contract — at most one thread calls push-side methods
// and at most one thread calls pop-side methods — makes the UnsafeCell
// cursors data-race free; everything else is atomics.
unsafe impl<T: Send> Send for SpscQueue<T> {}
// SAFETY: same argument as Send above — shared references only expose the
// single-producer/single-consumer protocol, whose cursor cells are never
// touched by both sides.
unsafe impl<T: Send> Sync for SpscQueue<T> {}

/// Outcome of a non-blocking pop.
#[derive(Debug, PartialEq, Eq)]
pub enum PopResult<T> {
    /// An item.
    Item(T),
    /// Queue momentarily empty (stream still open).
    Empty,
    /// Stream closed and fully drained.
    Closed,
}

/// Outcome of a failed non-blocking push (item handed back).
#[derive(Debug, PartialEq, Eq)]
pub enum PushError<T> {
    /// At capacity.
    Full(T),
    /// Stream already closed (or closed by the control plane).
    Closed(T),
}

impl<T: Send> SpscQueue<T> {
    /// New queue with `capacity` items (min 1) and `item_bytes` = d̄.
    pub fn new(capacity: usize, item_bytes: usize) -> Self {
        let capacity = capacity.max(1);
        let first = Block::alloc();
        SpscQueue {
            prod: CachePadded::new(UnsafeCell::new(ProdState {
                block: first,
                idx: 0,
                tail: 0,
                head_cache: 0,
            })),
            cons: CachePadded::new(UnsafeCell::new(ConsState {
                block: first,
                idx: 0,
                head: 0,
                tail_cache: 0,
            })),
            capacity: AtomicUsize::new(capacity),
            closed: AtomicBool::new(false),
            poisoned: AtomicBool::new(false),
            prod_waiter: CachePadded::new(Waiter::new()),
            cons_waiter: CachePadded::new(Waiter::new()),
            counters: QueueCounters::new(item_bytes),
        }
    }

    /// Instrumentation block (shared with the monitor).
    pub fn counters(&self) -> &QueueCounters {
        &self.counters
    }

    /// Current item count: `tail − head`, computed on demand. Head is
    /// loaded first — it can only trail the tail, so the difference is
    /// non-negative under any interleaving.
    #[inline]
    pub fn len(&self) -> usize {
        let head = self.counters.head_index().load(Ordering::Relaxed);
        let tail = self.counters.tail_index().load(Ordering::Acquire);
        tail.saturating_sub(head) as usize
    }

    /// True when no items are in flight.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Admission capacity.
    #[inline]
    pub fn capacity(&self) -> usize {
        self.capacity.load(Ordering::Relaxed)
    }

    /// Atomically change the admission capacity (monitor-callable). A
    /// single Relaxed store: the producer re-reads capacity on every
    /// admission check, so growth opens the §III non-blocking window on
    /// its very next attempt — including a parked one, which is woken
    /// here rather than left to sleep out its park timeout.
    ///
    /// **Shrink semantics:** a shrink below the current occupancy never
    /// drops or blocks items already queued — it only gates *new*
    /// admissions (`try_push` reports `Full`) until the consumer drains
    /// the stream below the new cap, at which point admission reopens by
    /// itself. The controller audits this deferred window with a
    /// `ControlEvent::Note` ("below occupancy") so a mid-drain scrape
    /// showing `len() > capacity()` is explicable from the event ring.
    /// The ring never returns memory on shrink (slots are a fixed block);
    /// the segmented backend retires drained segments as that drain
    /// happens (see [`super::SegmentedSpsc`]).
    pub fn set_capacity(&self, cap: usize) {
        self.capacity.store(cap.max(1), Ordering::Relaxed);
        self.prod_waiter.wake();
    }

    /// Has the stream been closed?
    #[inline]
    pub fn is_closed(&self) -> bool {
        self.closed.load(Ordering::Acquire)
    }

    /// Closed *and* drained — nothing will ever arrive again.
    #[inline]
    pub fn is_finished(&self) -> bool {
        self.is_closed() && self.is_empty()
    }

    /// Close the stream (producer side, or control plane). Idempotent.
    /// Wakes both ends so no thread stays parked on a dead stream.
    pub fn close(&self) {
        self.closed.store(true, Ordering::Release);
        self.prod_waiter.wake();
        self.cons_waiter.wake();
    }

    /// Poison the stream: a terminal state distinct from a clean close,
    /// set when a peer kernel panicked or the run was force-terminated.
    /// Mechanically it *is* a close — both ends unpark immediately, the
    /// producer gets `PushError::Closed` back, the consumer drains and
    /// then sees `Closed` — but `is_poisoned()` stays true so teardown
    /// can tell "finished" from "died" and audit stranded items as lost.
    /// Idempotent; poisoning an already-closed queue just sets the flag.
    pub fn poison(&self) {
        self.poisoned.store(true, Ordering::Release);
        self.close();
    }

    /// Was this stream poisoned (closed by a fault, not by completion)?
    #[inline]
    pub fn is_poisoned(&self) -> bool {
        self.poisoned.load(Ordering::Acquire)
    }

    /// Write `v` into the next unpublished slot, growing the segment
    /// chain as needed. Does not publish.
    #[inline]
    fn write_slot(&self, st: &mut ProdState<T>, v: T) {
        if st.idx == BLOCK {
            let nb = Block::alloc();
            // SAFETY: `st.block` is the producer-owned live tail block and
            // stays allocated until the consumer retires it. Link before
            // publish: the consumer discovers `next` only via an Acquire
            // tail load that postdates this store.
            unsafe { (*st.block).next.store(nb, Ordering::Release) };
            st.block = nb;
            st.idx = 0;
        }
        // SAFETY: the slot at (block, idx) is unpublished — ours to write.
        unsafe {
            (*(*st.block).slots[st.idx].get()).write(v);
        }
        st.idx += 1;
    }

    /// Read the next published slot, retiring exhausted blocks. The
    /// caller must have established `head < tail` (an item exists), which
    /// also guarantees the `next` link of an exhausted block is set.
    #[inline]
    fn read_slot(&self, st: &mut ConsState<T>) -> T {
        if st.idx == BLOCK {
            // SAFETY: `st.block` is the consumer-owned live head block; the
            // caller established an item exists past it, so the producer
            // linked `next` before publishing that item.
            let next = unsafe { (*st.block).next.load(Ordering::Acquire) };
            debug_assert!(!next.is_null(), "published item but next block missing");
            // SAFETY: we are past every slot of the old block, and the
            // producer moved on when it linked `next`.
            unsafe { drop(Box::from_raw(st.block)) };
            st.block = next;
            st.idx = 0;
        }
        // SAFETY: the Acquire that refreshed tail_cache made this slot's
        // write visible; it is published and not yet consumed.
        let v = unsafe { (*(*st.block).slots[st.idx].get()).assume_init_read() };
        st.idx += 1;
        v
    }

    /// Publish `pushed` freshly written items with one Release store and
    /// wake a parked consumer.
    #[inline]
    fn publish(&self, st: &mut ProdState<T>, pushed: u64) {
        st.tail = st.tail.wrapping_add(pushed);
        self.counters.tail_index().store(st.tail, Ordering::Release);
        self.cons_waiter.wake();
    }

    /// Non-blocking push. ⚠ producer thread only.
    ///
    /// Fast path: zero peer-cache-line reads — the capacity check runs
    /// against the producer's cached head snapshot, refreshed only on
    /// apparent full.
    #[inline]
    pub fn try_push(&self, v: T) -> Result<(), PushError<T>> {
        if self.closed.load(Ordering::Relaxed) {
            return Err(PushError::Closed(v));
        }
        // SAFETY: single producer — we are the only toucher of `prod`.
        let st = unsafe { &mut *self.prod.get() };
        let cap = self.capacity.load(Ordering::Relaxed) as u64;
        if st.tail.wrapping_sub(st.head_cache) >= cap {
            // Apparent full: only now touch the consumer's cache line.
            st.head_cache = self.counters.head_index().load(Ordering::Relaxed);
            if st.tail.wrapping_sub(st.head_cache) >= cap {
                return Err(PushError::Full(v));
            }
        }
        self.write_slot(st, v);
        self.publish(st, 1);
        Ok(())
    }

    /// Non-blocking bulk push: moves items out of `iter` while admission
    /// space remains, then publishes **once**. Returns the number pushed;
    /// items still in the iterator were not consumed. Returns 0 without
    /// touching the iterator when the stream is closed.
    ///
    /// Panic-safe: if `iter.next()` unwinds mid-batch, the items already
    /// written are published on the way out (drop guard), so the producer
    /// cursor and the published tail never desynchronize.
    pub fn try_push_iter<I>(&self, iter: &mut I) -> usize
    where
        I: Iterator<Item = T>,
    {
        if self.closed.load(Ordering::Relaxed) {
            return 0;
        }
        /// Publishes the written-but-unpublished run on drop — the
        /// normal exit path and the `iter.next()` unwind path alike.
        struct BatchGuard<'a, T: Send> {
            q: &'a SpscQueue<T>,
            st: &'a mut ProdState<T>,
            pushed: u64,
        }
        impl<T: Send> Drop for BatchGuard<'_, T> {
            fn drop(&mut self) {
                if self.pushed > 0 {
                    self.q.publish(self.st, self.pushed);
                }
            }
        }
        // SAFETY: single producer.
        let st = unsafe { &mut *self.prod.get() };
        let cap = self.capacity.load(Ordering::Relaxed) as u64;
        let mut g = BatchGuard { q: self, st, pushed: 0 };
        loop {
            let used = g.st.tail.wrapping_add(g.pushed).wrapping_sub(g.st.head_cache);
            let mut free = cap.saturating_sub(used);
            if free == 0 {
                let head = self.counters.head_index().load(Ordering::Relaxed);
                if head == g.st.head_cache {
                    break; // genuinely full
                }
                g.st.head_cache = head;
                continue;
            }
            while free > 0 {
                match iter.next() {
                    Some(v) => {
                        self.write_slot(g.st, v);
                        g.pushed += 1;
                        free -= 1;
                    }
                    None => return g.pushed as usize, // guard publishes
                }
            }
        }
        g.pushed as usize // guard publishes on drop
    }

    /// Blocking bulk push: delivers **every** item of `iter`, batching
    /// publishes while space is available and falling back to the
    /// adaptive-backoff [`SpscQueue::push`] when full. On
    /// `Err(PushError::Closed(v))`, `v` is the first undelivered item;
    /// the iterator's remaining items are dropped with it.
    pub fn push_iter<I>(&self, iter: I) -> Result<usize, PushError<T>>
    where
        I: IntoIterator<Item = T>,
    {
        let mut it = iter.into_iter();
        let mut n = self.try_push_iter(&mut it);
        loop {
            match it.next() {
                None => return Ok(n),
                Some(v) => match self.push(v) {
                    Ok(()) => n += 1,
                    Err(e) => return Err(e),
                },
            }
            n += self.try_push_iter(&mut it);
        }
    }

    /// Blocking push: adaptive spin → yield → park while full, recording
    /// blocked *duration* into the counters as the wait progresses.
    /// Returns the item if the queue is closed.
    pub fn push(&self, v: T) -> Result<(), PushError<T>> {
        match self.try_push(v) {
            Ok(()) => Ok(()),
            Err(PushError::Closed(x)) => Err(PushError::Closed(x)),
            Err(PushError::Full(x)) => self.push_slow(x),
        }
    }

    #[cold]
    fn push_slow(&self, mut v: T) -> Result<(), PushError<T>> {
        // The guard flushes blocked time and clears the wait marker on
        // every return path (and on unwind).
        let mut wait = WaitGuard::new(&self.counters, true);
        let mut pass: u32 = 0;
        let mut park_ns = PARK_MIN_NS;
        loop {
            match self.try_push(v) {
                Ok(()) => return Ok(()),
                Err(PushError::Closed(x)) => return Err(PushError::Closed(x)),
                Err(PushError::Full(x)) => v = x,
            }
            pass = pass.saturating_add(1);
            if pass <= SPIN_PASSES {
                std::hint::spin_loop();
                continue;
            }
            // Checkpoint so a concurrent monitor sample sees the block
            // while it is happening, not only after it resolves.
            wait.flush();
            if pass <= SPIN_PASSES + YIELD_PASSES {
                std::thread::yield_now();
                continue;
            }
            // Park until the consumer's next publish (or the safety-net
            // timeout). Raise the flag, then re-check, then park.
            self.prod_waiter.prepare();
            match self.try_push(v) {
                Ok(()) => {
                    self.prod_waiter.cancel();
                    return Ok(());
                }
                Err(PushError::Closed(x)) => {
                    self.prod_waiter.cancel();
                    return Err(PushError::Closed(x));
                }
                Err(PushError::Full(x)) => {
                    v = x;
                    std::thread::park_timeout(Duration::from_nanos(park_ns));
                    self.prod_waiter.cancel();
                    park_ns = (park_ns * 2).min(PARK_MAX_NS);
                }
            }
        }
    }

    /// Non-blocking pop. ⚠ consumer thread only.
    ///
    /// Fast path: zero peer-cache-line reads while the cached tail says
    /// items remain.
    #[inline]
    pub fn try_pop(&self) -> PopResult<T> {
        // SAFETY: single consumer — we are the only toucher of `cons`.
        let st = unsafe { &mut *self.cons.get() };
        if st.head == st.tail_cache {
            // Apparent empty: refresh the cached tail. The Acquire pairs
            // with the producer's Release publish, making slot writes and
            // block links visible.
            st.tail_cache = self.counters.tail_index().load(Ordering::Acquire);
            if st.head == st.tail_cache {
                if self.closed.load(Ordering::Acquire) {
                    // close() follows the final publish: re-read tail
                    // after observing `closed` so this verdict is final.
                    st.tail_cache = self.counters.tail_index().load(Ordering::Acquire);
                    if st.head == st.tail_cache {
                        return PopResult::Closed;
                    }
                } else {
                    return PopResult::Empty;
                }
            }
        }
        let v = self.read_slot(st);
        st.head = st.head.wrapping_add(1);
        self.counters.head_index().store(st.head, Ordering::Release);
        self.prod_waiter.wake();
        PopResult::Item(v)
    }

    /// Non-blocking bulk pop: appends up to `max` items to `out`, then
    /// publishes the head **once**. Returns the count (0 ⇒ momentarily
    /// empty *or* closed-and-drained — use [`SpscQueue::try_pop`] or
    /// [`SpscQueue::is_finished`] to distinguish).
    pub fn pop_batch(&self, out: &mut Vec<T>, max: usize) -> usize {
        if max == 0 {
            return 0;
        }
        // SAFETY: single consumer.
        let st = unsafe { &mut *self.cons.get() };
        let mut avail = st.tail_cache.wrapping_sub(st.head);
        if avail == 0 {
            st.tail_cache = self.counters.tail_index().load(Ordering::Acquire);
            avail = st.tail_cache.wrapping_sub(st.head);
            if avail == 0 {
                return 0;
            }
        }
        let take = (avail.min(max as u64)) as usize;
        out.reserve(take);
        for _ in 0..take {
            out.push(self.read_slot(st));
        }
        st.head = st.head.wrapping_add(take as u64);
        self.counters.head_index().store(st.head, Ordering::Release);
        self.prod_waiter.wake();
        take
    }

    /// Blocking pop: adaptive spin → yield → park while empty, recording
    /// blocked duration. `None` ⇒ closed and drained.
    pub fn pop(&self) -> Option<T> {
        match self.try_pop() {
            PopResult::Item(v) => Some(v),
            PopResult::Closed => None,
            PopResult::Empty => self.pop_slow(),
        }
    }

    #[cold]
    fn pop_slow(&self) -> Option<T> {
        // See push_slow: the guard keeps the in-progress wait visible to
        // samplers and settles the accounting on every exit path.
        let mut wait = WaitGuard::new(&self.counters, false);
        let mut pass: u32 = 0;
        let mut park_ns = PARK_MIN_NS;
        loop {
            match self.try_pop() {
                PopResult::Item(v) => return Some(v),
                PopResult::Closed => return None,
                PopResult::Empty => {}
            }
            pass = pass.saturating_add(1);
            if pass <= SPIN_PASSES {
                std::hint::spin_loop();
                continue;
            }
            wait.flush();
            if pass <= SPIN_PASSES + YIELD_PASSES {
                std::thread::yield_now();
                continue;
            }
            self.cons_waiter.prepare();
            match self.try_pop() {
                PopResult::Item(v) => {
                    self.cons_waiter.cancel();
                    return Some(v);
                }
                PopResult::Closed => {
                    self.cons_waiter.cancel();
                    return None;
                }
                PopResult::Empty => {
                    std::thread::park_timeout(Duration::from_nanos(park_ns));
                    self.cons_waiter.cancel();
                    park_ns = (park_ns * 2).min(PARK_MAX_NS);
                }
            }
        }
    }
}

impl<T> Drop for SpscQueue<T> {
    fn drop(&mut self) {
        // SAFETY: &mut self — no concurrent access remains.
        let cons = unsafe { &mut *self.cons.get() };
        let tail = self.counters.total_pushes();
        let mut remaining = tail.saturating_sub(cons.head);
        let mut block = cons.block;
        let mut idx = cons.idx;
        // Drop all published-but-unconsumed items.
        while remaining > 0 {
            if idx == BLOCK {
                // SAFETY: items remain past this block, so the producer
                // linked `next` before publishing them; &mut self means no
                // other thread can still reach the old block.
                let next = unsafe { (*block).next.load(Ordering::Relaxed) };
                // SAFETY: every slot of this block was consumed or is being
                // drained here; the block came from Box::into_raw in alloc().
                unsafe { drop(Box::from_raw(block)) };
                block = next;
                idx = 0;
                continue;
            }
            // SAFETY: slots in [cons.idx, tail) were published (written)
            // and never consumed, so each holds an initialized T.
            unsafe {
                (*(*block).slots[idx].get()).assume_init_drop();
            }
            idx += 1;
            remaining -= 1;
        }
        // Free the remaining chain of (now empty) blocks.
        while !block.is_null() {
            // SAFETY: &mut self — the chain is exclusively ours; each block
            // came from Box::into_raw in alloc().
            let next = unsafe { (*block).next.load(Ordering::Relaxed) };
            // SAFETY: see above; all items in it were already dropped.
            unsafe { drop(Box::from_raw(block)) };
            block = next;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order_single_thread() {
        let q = SpscQueue::new(16, 8);
        for i in 0..10u64 {
            q.try_push(i).unwrap();
        }
        for i in 0..10u64 {
            assert_eq!(q.try_pop(), PopResult::Item(i));
        }
        assert_eq!(q.try_pop(), PopResult::Empty);
    }

    #[test]
    fn capacity_enforced() {
        let q = SpscQueue::new(4, 8);
        for i in 0..4u64 {
            q.try_push(i).unwrap();
        }
        match q.try_push(99) {
            Err(PushError::Full(99)) => {}
            other => panic!("expected Full, got {other:?}"),
        }
        assert_eq!(q.len(), 4);
    }

    #[test]
    fn resize_opens_admission() {
        let q = SpscQueue::new(2, 8);
        q.try_push(0u64).unwrap();
        q.try_push(1).unwrap();
        assert!(matches!(q.try_push(2), Err(PushError::Full(_))));
        q.set_capacity(4); // §III: the monitor's resize trick
        q.try_push(2).unwrap();
        q.try_push(3).unwrap();
        assert_eq!(q.len(), 4);
        // Shrinking below occupancy only gates new admissions.
        q.set_capacity(1);
        assert!(matches!(q.try_push(4), Err(PushError::Full(_))));
        assert_eq!(q.try_pop(), PopResult::Item(0));
    }

    #[test]
    fn shrink_below_occupancy_defers_until_drained() {
        // Regression for the advisor's shrink path: capacity 16 with 10
        // queued, shrunk to 4. No item may be lost, admission must stay
        // gated while len > cap, and must reopen exactly when the
        // consumer drains below the new cap — with no second resize.
        let q = SpscQueue::new(16, 8);
        for i in 0..10u64 {
            q.try_push(i).unwrap();
        }
        q.set_capacity(4);
        assert_eq!(q.len(), 10, "shrink must not drop queued items");
        assert_eq!(q.capacity(), 4);
        // Gated the whole way down to the cap…
        for expect in 0..6u64 {
            assert!(
                matches!(q.try_push(99), Err(PushError::Full(_))),
                "len {} > cap must gate admission",
                q.len()
            );
            assert_eq!(q.try_pop(), PopResult::Item(expect));
        }
        // …and open again the moment occupancy dips below it.
        assert_eq!(q.len(), 4);
        assert!(matches!(q.try_push(99), Err(PushError::Full(_))));
        assert_eq!(q.try_pop(), PopResult::Item(6));
        q.try_push(10).unwrap();
        // FIFO order across the squeeze is intact.
        for expect in [7u64, 8, 9, 10] {
            assert_eq!(q.try_pop(), PopResult::Item(expect));
        }
        assert!(q.is_empty());
    }

    #[test]
    fn close_semantics() {
        let q = SpscQueue::new(8, 8);
        q.try_push(1u64).unwrap();
        q.close();
        assert!(matches!(q.try_push(2), Err(PushError::Closed(_))));
        assert_eq!(q.try_pop(), PopResult::Item(1));
        assert_eq!(q.try_pop(), PopResult::Closed);
        assert_eq!(q.pop(), None);
        assert!(q.is_finished());
    }

    #[test]
    fn poison_is_a_close_with_a_verdict() {
        let q = SpscQueue::new(8, 8);
        q.try_push(1u64).unwrap();
        assert!(!q.is_poisoned());
        q.poison();
        // Poison behaves exactly like close on the data path…
        assert!(q.is_closed());
        assert!(matches!(q.try_push(2), Err(PushError::Closed(_))));
        assert_eq!(q.try_pop(), PopResult::Item(1));
        assert_eq!(q.try_pop(), PopResult::Closed);
        // …but the terminal verdict is distinguishable.
        assert!(q.is_poisoned());
        // Idempotent, and a plain close never sets it.
        q.poison();
        assert!(q.is_poisoned());
        let q2 = SpscQueue::<u64>::new(8, 8);
        q2.close();
        assert!(!q2.is_poisoned());
    }

    #[test]
    fn poison_unparks_both_ends() {
        let q = Arc::new(SpscQueue::<u64>::new(1, 8));
        q.try_push(0).unwrap();
        let qp = q.clone();
        let prod = std::thread::spawn(move || qp.push(1));
        let q2 = Arc::new(SpscQueue::<u64>::new(1, 8));
        let qc = q2.clone();
        let cons = std::thread::spawn(move || qc.pop());
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.poison();
        q2.poison();
        assert!(matches!(prod.join().unwrap(), Err(PushError::Closed(1))));
        assert_eq!(cons.join().unwrap(), None);
    }

    #[test]
    fn crosses_block_boundaries() {
        let q = SpscQueue::new(BLOCK * 3, 8);
        for i in 0..(BLOCK as u64 * 2 + 17) {
            q.try_push(i).unwrap();
        }
        for i in 0..(BLOCK as u64 * 2 + 17) {
            assert_eq!(q.try_pop(), PopResult::Item(i));
        }
        assert_eq!(q.try_pop(), PopResult::Empty);
    }

    #[test]
    fn indices_are_the_counters() {
        let q = SpscQueue::new(8, 16);
        q.try_push(1u64).unwrap();
        q.try_push(2).unwrap();
        let _ = q.try_pop();
        let s = q.counters().sample();
        assert_eq!(s.tc_tail, 2);
        assert_eq!(s.tc_head, 1);
        assert_eq!(q.counters().total_pushes(), 2);
        assert_eq!(q.counters().total_pops(), 1);
        assert_eq!(q.counters().item_bytes(), 16);
        // The totals are literally the indices: len agrees.
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn batched_roundtrip_across_blocks() {
        let n = BLOCK as u64 * 2 + 100;
        let q = SpscQueue::new(n as usize, 8);
        let mut it = 0..n;
        assert_eq!(q.try_push_iter(&mut it), n as usize);
        assert!(it.next().is_none());
        // Full queue admits nothing more.
        let mut more = 0..5u64;
        assert_eq!(q.try_push_iter(&mut more), 0);
        assert_eq!(more.next(), Some(0), "iterator must not lose items");
        // One publish covered the whole batch:
        let s = q.counters().sample();
        assert_eq!(s.tc_tail, n);
        // Batched drain, bounded by `max`.
        let mut out = Vec::new();
        assert_eq!(q.pop_batch(&mut out, 64), 64);
        assert_eq!(q.pop_batch(&mut out, usize::MAX), n as usize - 64);
        assert_eq!(q.pop_batch(&mut out, 8), 0);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as u64);
        }
        assert_eq!(q.counters().total_pops(), n);
    }

    #[test]
    fn push_iter_blocks_until_delivered() {
        let q = Arc::new(SpscQueue::new(8, 8));
        let n = 50_000u64;
        let qp = q.clone();
        let prod = std::thread::spawn(move || {
            let pushed = qp.push_iter(0..n).unwrap();
            qp.close();
            pushed
        });
        let qc = q.clone();
        let cons = std::thread::spawn(move || {
            let mut out = Vec::new();
            let mut expect = 0u64;
            loop {
                let got = qc.pop_batch(&mut out, 32);
                if got == 0 {
                    match qc.try_pop() {
                        PopResult::Item(v) => out.push(v),
                        PopResult::Closed => break,
                        PopResult::Empty => std::thread::yield_now(),
                    }
                }
                for v in out.drain(..) {
                    assert_eq!(v, expect);
                    expect += 1;
                }
            }
            expect
        });
        assert_eq!(prod.join().unwrap(), n as usize);
        assert_eq!(cons.join().unwrap(), n);
    }

    #[test]
    fn blocked_duration_recorded_by_blocking_paths() {
        let q = Arc::new(SpscQueue::new(1, 8));
        // Fill, then have a producer thread block on a full queue.
        q.try_push(0u64).unwrap();
        let qp = q.clone();
        let t = std::thread::spawn(move || {
            qp.push(1).unwrap();
        });
        // Give the producer time to block (and park), then drain.
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert_eq!(q.try_pop(), PopResult::Item(0));
        t.join().unwrap();
        let s = q.counters().sample();
        assert!(s.write_blocked(), "producer block not recorded");
        assert!(
            s.write_blocked_ns >= 5_000_000,
            "expected ≥5 ms of recorded block, got {} ns",
            s.write_blocked_ns
        );
        assert_eq!(s.tc_tail, 2);
        assert!(s.tail_valid_within(100_000_000));
        assert!(!s.tail_valid());
    }

    #[test]
    fn in_progress_park_is_visible_to_sampler() {
        // A sample taken while an end is parked (unable to flush its
        // blocked time) must still see the wait — otherwise every
        // monitor window inside a long park reads as a valid zero-rate
        // observation (§IV regression).
        let q = Arc::new(SpscQueue::<u64>::new(8, 8));
        let qc = q.clone();
        let cons = std::thread::spawn(move || qc.pop());
        std::thread::sleep(std::time::Duration::from_millis(30));
        // Consumer is mid-wait (parked or yielding) right now.
        let s = q.counters().sample();
        assert!(
            s.read_blocked_ns > 0,
            "in-progress wait invisible to a concurrent sample"
        );
        assert!(!s.head_valid(), "starved window must not read as valid");
        q.try_push(9).unwrap();
        assert_eq!(cons.join().unwrap(), Some(9));
    }

    #[test]
    fn parked_consumer_wakes_on_publish() {
        let q = Arc::new(SpscQueue::new(8, 8));
        let qc = q.clone();
        let cons = std::thread::spawn(move || qc.pop());
        // Let the consumer walk the full backoff ladder into park.
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.try_push(7u64).unwrap();
        assert_eq!(cons.join().unwrap(), Some(7));
        let s = q.counters().sample();
        assert!(s.read_blocked(), "consumer block not recorded");
    }

    #[test]
    fn parked_ends_wake_on_close() {
        let q = Arc::new(SpscQueue::<u64>::new(1, 8));
        q.try_push(0).unwrap();
        let qp = q.clone();
        let prod = std::thread::spawn(move || qp.push(1));
        let q2 = Arc::new(SpscQueue::<u64>::new(1, 8));
        let qc = q2.clone();
        let cons = std::thread::spawn(move || qc.pop());
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        q2.close();
        assert!(matches!(prod.join().unwrap(), Err(PushError::Closed(1))));
        assert_eq!(cons.join().unwrap(), None);
    }

    #[test]
    fn spsc_stress_no_loss_no_dup() {
        let q = Arc::new(SpscQueue::new(64, 8));
        let n = 1_000_000u64;
        let qp = q.clone();
        let prod = std::thread::spawn(move || {
            for i in 0..n {
                qp.push(i).unwrap();
            }
            qp.close();
        });
        let qc = q.clone();
        let cons = std::thread::spawn(move || {
            let mut expect = 0u64;
            let mut sum = 0u64;
            while let Some(v) = qc.pop() {
                assert_eq!(v, expect, "out of order");
                expect += 1;
                sum = sum.wrapping_add(v);
            }
            (expect, sum)
        });
        prod.join().unwrap();
        let (count, sum) = cons.join().unwrap();
        assert_eq!(count, n);
        assert_eq!(sum, n * (n - 1) / 2);
        assert_eq!(q.counters().total_pushes(), n);
        assert_eq!(q.counters().total_pops(), n);
    }

    #[test]
    fn spsc_stress_batched_no_loss_no_dup() {
        let q = Arc::new(SpscQueue::new(256, 8));
        let n = 1_000_000u64;
        let qp = q.clone();
        let prod = std::thread::spawn(move || {
            let mut i = 0u64;
            while i < n {
                let hi = (i + 128).min(n);
                qp.push_iter(i..hi).unwrap();
                i = hi;
            }
            qp.close();
        });
        let qc = q.clone();
        let cons = std::thread::spawn(move || {
            let mut out = Vec::with_capacity(128);
            let mut expect = 0u64;
            loop {
                if qc.pop_batch(&mut out, 128) == 0 {
                    match qc.pop() {
                        Some(v) => out.push(v),
                        None => break,
                    }
                }
                for v in out.drain(..) {
                    assert_eq!(v, expect, "out of order");
                    expect += 1;
                }
            }
            expect
        });
        prod.join().unwrap();
        assert_eq!(cons.join().unwrap(), n);
        assert_eq!(q.counters().total_pushes(), n);
        assert_eq!(q.counters().total_pops(), n);
    }

    #[test]
    fn drop_releases_unconsumed_items() {
        // Use Arc'd payloads to observe drops.
        let marker = Arc::new(());
        {
            let q = SpscQueue::new(1024, 8);
            for _ in 0..(BLOCK + 13) {
                q.try_push(marker.clone()).unwrap();
            }
            // Consume a few across the boundary to exercise mixed state.
            for _ in 0..7 {
                let _ = q.try_pop();
            }
        } // q dropped here
        assert_eq!(Arc::strong_count(&marker), 1, "leaked items on drop");
    }

    #[test]
    fn resize_while_streaming() {
        let q = Arc::new(SpscQueue::new(4, 8));
        let n = 100_000u64;
        let qp = q.clone();
        let prod = std::thread::spawn(move || {
            for i in 0..n {
                qp.push(i).unwrap();
            }
            qp.close();
        });
        let qm = q.clone();
        let monitor = std::thread::spawn(move || {
            // Monitor thrashes the capacity while data flows.
            for c in (1..=64u64).cycle().take(10_000) {
                qm.set_capacity(c as usize);
                std::hint::spin_loop();
            }
        });
        let qc = q.clone();
        let cons = std::thread::spawn(move || {
            let mut expect = 0u64;
            while let Some(v) = qc.pop() {
                assert_eq!(v, expect);
                expect += 1;
            }
            expect
        });
        prod.join().unwrap();
        monitor.join().unwrap();
        assert_eq!(cons.join().unwrap(), n);
    }

    #[test]
    fn concurrent_sampling_conserves_counts_end_to_end() {
        // Acceptance: sum of monitor samples + residue == monotonic
        // totals while a stream runs and a sampler races both ends.
        let q = Arc::new(SpscQueue::new(128, 8));
        let n = 400_000u64;
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let qp = q.clone();
        let prod = std::thread::spawn(move || {
            for i in 0..n {
                qp.push(i).unwrap();
            }
            qp.close();
        });
        let qm = q.clone();
        let stop_m = stop.clone();
        let mon = std::thread::spawn(move || {
            let (mut heads, mut tails) = (0u64, 0u64);
            while !stop_m.load(Ordering::Relaxed) {
                let s = qm.counters().sample();
                heads += s.tc_head;
                tails += s.tc_tail;
                std::thread::yield_now();
            }
            (heads, tails)
        });
        let qc = q.clone();
        let cons = std::thread::spawn(move || {
            let mut count = 0u64;
            while qc.pop().is_some() {
                count += 1;
            }
            count
        });
        prod.join().unwrap();
        assert_eq!(cons.join().unwrap(), n);
        stop.store(true, Ordering::Relaxed);
        let (heads, tails) = mon.join().unwrap();
        let residue = q.counters().sample();
        assert_eq!(heads + residue.tc_head, n, "head samples + residue != total");
        assert_eq!(tails + residue.tc_tail, n, "tail samples + residue != total");
        assert_eq!(q.counters().total_pushes(), n);
        assert_eq!(q.counters().total_pops(), n);
    }
}

/// Model-checks the head/tail/close publication protocol (not the full
/// segmented queue): the producer Release-publishes `tail` after a plain
/// slot write and Release-sets `closed` after the final publish; the
/// consumer Acquire-loads `tail`, must then observe the slot write, and
/// may conclude end-of-stream only after re-reading `tail` subsequent to
/// observing `closed`.
///
/// Off by default. The `loom` dev-dependency is declared under
/// `[target.'cfg(loom)']` in the manifest (loom's documented pattern), so
/// the default build never compiles it; the dedicated CI `loom` lane runs
/// `RUSTFLAGS="--cfg loom" cargo test --features loom --release --lib queue`.
#[cfg(all(test, feature = "loom", loom))]
mod loom_model {
    use loom::cell::UnsafeCell;
    use loom::sync::atomic::{AtomicBool, AtomicU64, Ordering};
    use loom::sync::Arc;

    struct Proto {
        tail: AtomicU64,
        head: AtomicU64,
        closed: AtomicBool,
        slots: [UnsafeCell<u64>; 2],
    }

    #[test]
    fn head_tail_close_ordering() {
        loom::model(|| {
            let p = Arc::new(Proto {
                tail: AtomicU64::new(0),
                head: AtomicU64::new(0),
                closed: AtomicBool::new(false),
                slots: [UnsafeCell::new(0), UnsafeCell::new(0)],
            });
            let q = p.clone();
            let prod = loom::thread::spawn(move || {
                for i in 0..2u64 {
                    // SAFETY: slot i is unpublished (tail == i), so the
                    // consumer never touches it concurrently.
                    q.slots[i as usize].with_mut(|s| unsafe { *s = i + 1 });
                    q.tail.store(i + 1, Ordering::Release);
                }
                q.closed.store(true, Ordering::Release);
            });
            let mut head = 0u64;
            let mut got = Vec::new();
            loop {
                let tail = p.tail.load(Ordering::Acquire);
                if head == tail {
                    if p.closed.load(Ordering::Acquire) {
                        // The close-is-final rule under test: re-read the
                        // tail after observing `closed`.
                        if head == p.tail.load(Ordering::Acquire) {
                            break;
                        }
                        continue;
                    }
                    loom::thread::yield_now();
                    continue;
                }
                // SAFETY: head < tail was observed via Acquire, so the
                // producer's write to this slot happened-before this read.
                let v = p.slots[head as usize].with(|s| unsafe { *s });
                assert_eq!(v, head + 1, "read an unpublished slot");
                got.push(v);
                head += 1;
                p.head.store(head, Ordering::Release);
            }
            prod.join().unwrap();
            assert_eq!(got, vec![1, 2], "lost or reordered items");
        });
    }
}
