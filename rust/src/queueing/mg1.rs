//! M/G/1 and G/G/1 analytics — what the measured rates feed once the
//! `classify` module has identified the service process (§VII: "quite
//! useful if the known distribution enables the use of a closed form
//! modeling solution").
//!
//! * Pollaczek–Khinchine for M/G/1 (exact),
//! * the M/D/1 specialization (deterministic service — the paper's other
//!   micro-benchmark family),
//! * Kingman's G/G/1 heavy-traffic approximation for everything else.

/// Mean wait in queue for M/G/1 by Pollaczek–Khinchine:
/// `Wq = (λ·E[S²]) / (2(1−ρ))` with `E[S²] = σ_s² + (1/μ)²`.
///
/// `lambda`, `mu` in items/sec; `cs2` is the squared coefficient of
/// variation of the service time (0 = deterministic, 1 = exponential).
pub fn mg1_mean_wait(lambda: f64, mu: f64, cs2: f64) -> f64 {
    assert!(lambda >= 0.0 && mu > 0.0 && cs2 >= 0.0);
    let rho = lambda / mu;
    assert!(rho < 1.0, "M/G/1 requires ρ < 1 (got {rho})");
    let es2 = (cs2 + 1.0) / (mu * mu); // E[S²] = (cs²+1)/μ²
    lambda * es2 / (2.0 * (1.0 - rho))
}

/// Mean number in queue (not in service) for M/G/1 (Little's law).
pub fn mg1_mean_queue_len(lambda: f64, mu: f64, cs2: f64) -> f64 {
    lambda * mg1_mean_wait(lambda, mu, cs2)
}

/// M/D/1 mean wait — the deterministic-service specialization (cs² = 0):
/// exactly half the M/M/1 wait.
pub fn md1_mean_wait(lambda: f64, mu: f64) -> f64 {
    mg1_mean_wait(lambda, mu, 0.0)
}

/// Kingman's G/G/1 approximation:
/// `Wq ≈ (ρ/(1−ρ)) · ((ca² + cs²)/2) · (1/μ)`.
pub fn gg1_kingman_wait(lambda: f64, mu: f64, ca2: f64, cs2: f64) -> f64 {
    assert!(lambda >= 0.0 && mu > 0.0);
    let rho = lambda / mu;
    assert!(rho < 1.0, "G/G/1 requires ρ < 1 (got {rho})");
    (rho / (1.0 - rho)) * ((ca2 + cs2) / 2.0) / mu
}

/// Rough buffer sizing from mean queue length: capacity that holds the
/// steady-state queue plus `headroom_sigmas` standard deviations
/// (geometric-tail heuristic: σ ≈ L·(1+cv)). Clamped to ≥ 1.
pub fn suggest_capacity(lambda: f64, mu: f64, cs2: f64, headroom_sigmas: f64) -> usize {
    if lambda >= mu {
        // Saturated: capacity only buys burst absorption; pick a large
        // default proportional to the arrival rate over a 10 ms horizon.
        return ((lambda * 0.01).ceil() as usize).max(64);
    }
    let l = mg1_mean_queue_len(lambda, mu, cs2);
    let sigma = l * (1.0 + cs2.sqrt());
    ((l + headroom_sigmas * sigma).ceil() as usize).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn md1_is_half_mm1() {
        let (lambda, mu) = (50.0, 100.0);
        let mm1 = mg1_mean_wait(lambda, mu, 1.0);
        let md1 = md1_mean_wait(lambda, mu);
        assert!((md1 - mm1 / 2.0).abs() < 1e-12);
    }

    #[test]
    fn mm1_matches_closed_form() {
        // M/M/1: Wq = ρ/(μ−λ).
        let (lambda, mu) = (60.0, 100.0);
        let rho: f64 = lambda / mu;
        let expect = rho / (mu - lambda);
        assert!((mg1_mean_wait(lambda, mu, 1.0) - expect).abs() < 1e-12);
    }

    #[test]
    fn kingman_matches_mm1_at_cv1() {
        // With ca² = cs² = 1 Kingman is exact for M/M/1.
        let (lambda, mu) = (80.0, 100.0);
        let rho: f64 = lambda / mu;
        let expect = rho / (mu - lambda);
        assert!((gg1_kingman_wait(lambda, mu, 1.0, 1.0) - expect).abs() < 1e-12);
    }

    #[test]
    fn wait_grows_with_utilization_and_variance() {
        assert!(mg1_mean_wait(90.0, 100.0, 1.0) > mg1_mean_wait(50.0, 100.0, 1.0));
        assert!(mg1_mean_wait(50.0, 100.0, 2.0) > mg1_mean_wait(50.0, 100.0, 0.0));
    }

    #[test]
    #[should_panic]
    fn saturated_mg1_panics() {
        mg1_mean_wait(100.0, 100.0, 1.0);
    }

    #[test]
    fn suggested_capacity_sane() {
        let c = suggest_capacity(50.0, 100.0, 1.0, 3.0);
        assert!(c >= 1 && c < 100, "c = {c}");
        // Higher utilization ⇒ bigger buffer.
        let c_hot = suggest_capacity(95.0, 100.0, 1.0, 3.0);
        assert!(c_hot > c);
        // Saturated path.
        assert!(suggest_capacity(200.0, 100.0, 1.0, 3.0) >= 64);
    }
}
