//! Queueing analytics (paper §II, Eq. 1; Kleinrock [12]).
//!
//! The service rates the monitor estimates feed analytic models like these
//! — and Eq. 1 itself explains *when* the monitor can expect to observe
//! non-blocking transactions at all (Fig. 4).

pub mod mg1;

/// M/M/1 (and M/M/1/C) closed forms.
pub mod mm1 {
    /// Eq. 1a: `k = ⌈μs·T⌉` — items the server consumes during a period.
    ///
    /// `mu_s` in items/sec, `t` in seconds.
    pub fn k_items(mu_s: f64, t: f64) -> u64 {
        (mu_s * t).ceil() as u64
    }

    /// Eq. 1b/1c: probability that an entire sampling period `T` sees only
    /// non-blocking **reads** — i.e. at least `k` items are available:
    /// `Pr = ρ^k`.
    pub fn pr_nonblocking_read(t: f64, rho: f64, mu_s: f64) -> f64 {
        assert!((0.0..=1.0).contains(&rho), "utilization must be in [0,1]");
        let k = k_items(mu_s, t);
        rho.powi(k as i32)
    }

    /// Eq. 1d: probability of non-blocking **writes** over `T` with output
    /// queue capacity `c`:
    /// `Pr = 1 − ρ^(C−k+1)` when `C ≥ μs·T`, else 0.
    pub fn pr_nonblocking_write(t: f64, c: u64, rho: f64, mu_s: f64) -> f64 {
        assert!((0.0..=1.0).contains(&rho));
        if (c as f64) < mu_s * t {
            return 0.0;
        }
        let k = k_items(mu_s, t);
        let exponent = c.saturating_sub(k).saturating_add(1);
        1.0 - rho.powi(exponent as i32)
    }

    /// Steady-state P(N = n) for M/M/1: `(1−ρ)ρⁿ`.
    pub fn p_n(rho: f64, n: u64) -> f64 {
        assert!((0.0..1.0).contains(&rho));
        (1.0 - rho) * rho.powi(n as i32)
    }

    /// Mean number in system: `ρ/(1−ρ)`.
    pub fn mean_in_system(rho: f64) -> f64 {
        assert!((0.0..1.0).contains(&rho));
        rho / (1.0 - rho)
    }

    /// Mean waiting time in queue (Little): `ρ/(μ(1−ρ))`.
    pub fn mean_wait(rho: f64, mu_s: f64) -> f64 {
        assert!(mu_s > 0.0);
        mean_in_system(rho) / mu_s
    }

    /// Blocking (loss) probability of the finite M/M/1/C queue:
    /// `P_C = (1−ρ)ρ^C / (1−ρ^{C+1})` (ρ ≠ 1), `1/(C+1)` at ρ = 1.
    pub fn blocking_probability(rho: f64, c: u64) -> f64 {
        assert!(rho >= 0.0);
        if (rho - 1.0).abs() < 1e-12 {
            return 1.0 / (c as f64 + 1.0);
        }
        (1.0 - rho) * rho.powi(c as i32) / (1.0 - rho.powi(c as i32 + 1))
    }

    /// Analytic buffer sizing — the paper's §I motivation: the smallest
    /// capacity whose blocking probability is below `target`. `None` if
    /// not reachable below `max_c` (ρ ≥ 1 always saturates).
    pub fn min_capacity_for_blocking(rho: f64, target: f64, max_c: u64) -> Option<u64> {
        assert!(target > 0.0 && target < 1.0);
        (1..=max_c).find(|&c| blocking_probability(rho, c) <= target)
    }
}

/// Server utilization ρ = λ/μ, clamped to [0, 1] for stability at the
/// boundary (measured rates can transiently exceed service rates).
pub fn utilization(lambda: f64, mu: f64) -> f64 {
    if mu <= 0.0 {
        return 1.0;
    }
    (lambda / mu).clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::mm1::*;
    use super::*;

    #[test]
    fn k_is_ceiling() {
        assert_eq!(k_items(1000.0, 0.0101), 11);
        assert_eq!(k_items(1000.0, 0.01), 10);
    }

    #[test]
    fn read_probability_decays_with_t() {
        // Fig. 4's shape: longer T ⇒ lower probability, faster server ⇒ lower.
        let rho = 0.9;
        let p1 = pr_nonblocking_read(0.001, rho, 1.0e5);
        let p2 = pr_nonblocking_read(0.01, rho, 1.0e5);
        assert!(p1 > p2, "{p1} !> {p2}");
        let slow = pr_nonblocking_read(0.001, rho, 1.0e4);
        assert!(slow > p1);
    }

    #[test]
    fn read_probability_bounds() {
        for &t in &[1e-6, 1e-4, 1e-2] {
            for &rho in &[0.1, 0.5, 0.99] {
                let p = pr_nonblocking_read(t, rho, 1.0e6);
                assert!((0.0..=1.0).contains(&p));
            }
        }
    }

    #[test]
    fn write_probability_zero_when_capacity_insufficient() {
        // C < μs·T ⇒ the server MUST block within the period.
        assert_eq!(pr_nonblocking_write(0.01, 10, 0.5, 1.0e4), 0.0);
        // C ≥ μs·T ⇒ positive.
        assert!(pr_nonblocking_write(0.001, 100, 0.5, 1.0e4) > 0.0);
    }

    #[test]
    fn write_probability_grows_with_capacity() {
        let a = pr_nonblocking_write(0.001, 20, 0.9, 1.0e4);
        let b = pr_nonblocking_write(0.001, 200, 0.9, 1.0e4);
        assert!(b > a);
    }

    #[test]
    fn pn_sums_to_one() {
        let rho = 0.7;
        let total: f64 = (0..500).map(|n| p_n(rho, n)).sum();
        assert!((total - 1.0).abs() < 1e-12);
    }

    #[test]
    fn mean_in_system_matches_sum() {
        let rho = 0.6;
        let by_sum: f64 = (0..2000).map(|n| n as f64 * p_n(rho, n)).sum();
        assert!((mean_in_system(rho) - by_sum).abs() < 1e-9);
    }

    #[test]
    fn blocking_probability_limits() {
        // Large C ⇒ → 0 for ρ < 1.
        assert!(blocking_probability(0.5, 60) < 1e-15);
        // ρ = 1 special case.
        assert!((blocking_probability(1.0, 9) - 0.1).abs() < 1e-12);
        // Monotone decreasing in C.
        assert!(blocking_probability(0.9, 5) > blocking_probability(0.9, 10));
    }

    #[test]
    fn buffer_sizing_finds_minimum() {
        let c = min_capacity_for_blocking(0.8, 0.01, 1000).unwrap();
        assert!(blocking_probability(0.8, c) <= 0.01);
        assert!(blocking_probability(0.8, c - 1) > 0.01);
        // Saturated server can't hit small targets.
        assert_eq!(min_capacity_for_blocking(1.0, 1e-6, 100), None);
    }

    #[test]
    fn utilization_clamps() {
        assert_eq!(utilization(5.0, 10.0), 0.5);
        assert_eq!(utilization(20.0, 10.0), 1.0);
        assert_eq!(utilization(5.0, 0.0), 1.0);
    }
}
