//! Report emission: CSV rows for every figure/table, plus summary stats.
//!
//! Every `benches/figNN_*.rs` target prints its series through this module
//! — one header + data rows on stdout, and a copy under `target/figures/`
//! so the paper's plots can be regenerated from files.

use std::fmt::Write as _;
use std::fs;
use std::io::Write as _;
use std::path::PathBuf;

use crate::stats::percentile;

/// A rectangular CSV table under construction.
#[derive(Debug, Clone, Default)]
pub struct Table {
    name: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(name: impl Into<String>, header: &[&str]) -> Self {
        Table {
            name: name.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (panics on column-count mismatch — a bench bug).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "table '{}': row width {} != header width {}",
            self.name,
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells.to_vec());
    }

    /// Convenience: append a row of formatted f64s.
    pub fn row_f(&mut self, cells: &[f64]) {
        self.row(&cells.iter().map(|v| format_g(*v)).collect::<Vec<_>>());
    }

    /// Mixed row helper.
    pub fn row_mixed(&mut self, cells: &[Cell]) {
        self.row(&cells.iter().map(|c| c.to_string()).collect::<Vec<_>>());
    }

    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    /// Serialize to CSV.
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.header.join(","));
        for r in &self.rows {
            let _ = writeln!(out, "{}", r.join(","));
        }
        out
    }

    /// Print to stdout with a banner, and save under `target/figures/`.
    pub fn emit(&self) -> std::io::Result<PathBuf> {
        println!("# --- {} ({} rows) ---", self.name, self.rows.len());
        print!("{}", self.to_csv());
        let dir = figures_dir();
        fs::create_dir_all(&dir)?;
        let path = dir.join(format!("{}.csv", self.name));
        let mut f = fs::File::create(&path)?;
        f.write_all(self.to_csv().as_bytes())?;
        Ok(path)
    }
}

/// Where figure CSVs land (`SF_FIGURES` or `target/figures`).
pub fn figures_dir() -> PathBuf {
    std::env::var("SF_FIGURES")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("target/figures"))
}

/// A heterogeneous cell.
#[derive(Debug, Clone)]
pub enum Cell {
    U(u64),
    I(i64),
    F(f64),
    S(String),
    B(bool),
}

impl std::fmt::Display for Cell {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Cell::U(v) => write!(f, "{v}"),
            Cell::I(v) => write!(f, "{v}"),
            Cell::F(v) => write!(f, "{}", format_g(*v)),
            Cell::S(v) => write!(f, "{v}"),
            Cell::B(v) => write!(f, "{v}"),
        }
    }
}

/// Compact general float formatting (trims trailing zeros, keeps precision).
pub fn format_g(v: f64) -> String {
    if v == 0.0 {
        return "0".to_string();
    }
    let a = v.abs();
    if (1e-4..1e9).contains(&a) {
        let s = format!("{v:.6}");
        let s = s.trim_end_matches('0').trim_end_matches('.');
        s.to_string()
    } else {
        format!("{v:.6e}")
    }
}

/// Five-number-ish summary used across benches: mean, sd, p5, p50, p95.
#[derive(Debug, Clone, Copy)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub sd: f64,
    pub p5: f64,
    pub p50: f64,
    pub p95: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty());
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            sd: var.sqrt(),
            p5: percentile(&sorted, 5.0),
            p50: percentile(&sorted, 50.0),
            p95: percentile(&sorted, 95.0),
            min: sorted[0],
            max: sorted[n - 1],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new("test_fig", &["x", "y"]);
        t.row_f(&[1.0, 2.5]);
        t.row_mixed(&[Cell::U(3), Cell::S("hi".into())]);
        let csv = t.to_csv();
        assert_eq!(csv, "x,y\n1,2.5\n3,hi\n");
        assert_eq!(t.num_rows(), 2);
    }

    #[test]
    #[should_panic]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("bad", &["a", "b"]);
        t.row(&["only one".to_string()]);
    }

    #[test]
    fn format_g_cases() {
        assert_eq!(format_g(0.0), "0");
        assert_eq!(format_g(1.5), "1.5");
        assert_eq!(format_g(2.0), "2");
        assert!(format_g(1.0e-9).contains('e'));
    }

    #[test]
    fn summary_of_known_data() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.n, 100);
        assert!((s.mean - 50.5).abs() < 1e-12);
        assert!((s.p50 - 50.5).abs() < 1e-9);
        assert!(s.p5 < s.p50 && s.p50 < s.p95);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
    }
}
