//! Service-time distributions for the micro-benchmark kernels (paper §V-A).
//!
//! The paper drives each micro-benchmark kernel with a while-loop that burns
//! a sampled amount of time per item; "service time distributions are set as
//! either exponential or deterministic". The dual-phase experiments (§VI)
//! shift the distribution mean halfway through execution — modeled here by
//! [`ServiceProcess`] holding one distribution per phase.

use super::Xoshiro256pp;

/// A service-time distribution (nanoseconds per item).
#[derive(Debug, Clone, PartialEq)]
pub enum Distribution {
    /// Every item takes exactly `mean_ns` (M/D/1-style server).
    Deterministic { mean_ns: f64 },
    /// Exponentially distributed with mean `mean_ns` (M/M/1-style server).
    Exponential { mean_ns: f64 },
    /// Uniform on [lo_ns, hi_ns] — used by classification tests.
    Uniform { lo_ns: f64, hi_ns: f64 },
    /// Truncated normal (resampled below 0).
    Normal { mean_ns: f64, sd_ns: f64 },
}

impl Distribution {
    /// Construct from a service *rate* in MB/s and an item size in bytes —
    /// the paper parameterizes its kernels this way (0.8 → ~8 MB/s, 8-byte
    /// items).
    pub fn from_rate_mbps(kind: DistKind, rate_mbps: f64, item_bytes: usize) -> Self {
        assert!(rate_mbps > 0.0, "rate must be positive");
        let items_per_sec = rate_mbps * 1.0e6 / item_bytes as f64;
        let mean_ns = 1.0e9 / items_per_sec;
        match kind {
            DistKind::Deterministic => Distribution::Deterministic { mean_ns },
            DistKind::Exponential => Distribution::Exponential { mean_ns },
        }
    }

    /// Mean service time in ns.
    pub fn mean_ns(&self) -> f64 {
        match *self {
            Distribution::Deterministic { mean_ns } => mean_ns,
            Distribution::Exponential { mean_ns } => mean_ns,
            Distribution::Uniform { lo_ns, hi_ns } => 0.5 * (lo_ns + hi_ns),
            Distribution::Normal { mean_ns, .. } => mean_ns,
        }
    }

    /// The implied service rate in MB/s for the given item size.
    pub fn rate_mbps(&self, item_bytes: usize) -> f64 {
        let items_per_sec = 1.0e9 / self.mean_ns();
        items_per_sec * item_bytes as f64 / 1.0e6
    }

    /// Draw one service time (ns).
    #[inline]
    pub fn sample(&self, rng: &mut Xoshiro256pp, normal_cache: &mut Option<f64>) -> f64 {
        match *self {
            Distribution::Deterministic { mean_ns } => mean_ns,
            Distribution::Exponential { mean_ns } => rng.exponential(mean_ns),
            Distribution::Uniform { lo_ns, hi_ns } => rng.uniform(lo_ns, hi_ns),
            Distribution::Normal { mean_ns, sd_ns } => loop {
                let x = mean_ns + sd_ns * rng.standard_normal(normal_cache);
                if x >= 0.0 {
                    break x;
                }
            },
        }
    }

    /// Theoretical coefficient of variation (σ/μ) — used by `classify`.
    pub fn cv(&self) -> f64 {
        match *self {
            Distribution::Deterministic { .. } => 0.0,
            Distribution::Exponential { .. } => 1.0,
            Distribution::Uniform { lo_ns, hi_ns } => {
                let mean = 0.5 * (lo_ns + hi_ns);
                let sd = (hi_ns - lo_ns) / (12.0f64).sqrt();
                if mean == 0.0 {
                    0.0
                } else {
                    sd / mean
                }
            }
            Distribution::Normal { mean_ns, sd_ns } => {
                if mean_ns == 0.0 {
                    0.0
                } else {
                    sd_ns / mean_ns
                }
            }
        }
    }
}

/// Distribution family selector used by CLI/config.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DistKind {
    Deterministic,
    Exponential,
}

impl std::str::FromStr for DistKind {
    type Err = String;
    fn from_str(s: &str) -> std::result::Result<Self, String> {
        match s {
            "deterministic" | "det" | "d" => Ok(DistKind::Deterministic),
            "exponential" | "exp" | "m" => Ok(DistKind::Exponential),
            other => Err(format!("unknown distribution kind: {other}")),
        }
    }
}

/// A possibly phase-shifting service process.
///
/// Single-phase processes have one segment; the paper's dual-phase
/// micro-benchmark "moves the mean of the distribution halfway through
/// execution ... with reference to the number of data elements sent".
#[derive(Debug, Clone)]
pub struct ServiceProcess {
    /// (items-processed threshold at which the phase *ends*, distribution).
    /// The final phase's threshold is ignored (runs to completion).
    phases: Vec<(u64, Distribution)>,
    rng: Xoshiro256pp,
    normal_cache: Option<f64>,
    items_done: u64,
}

impl ServiceProcess {
    /// Single-phase process.
    pub fn single(dist: Distribution, seed: u64) -> Self {
        ServiceProcess {
            phases: vec![(u64::MAX, dist)],
            rng: Xoshiro256pp::new(seed),
            normal_cache: None,
            items_done: 0,
        }
    }

    /// Dual-phase process: `first` until `switch_at_items`, then `second`.
    pub fn dual(first: Distribution, second: Distribution, switch_at_items: u64, seed: u64) -> Self {
        ServiceProcess {
            phases: vec![(switch_at_items, first), (u64::MAX, second)],
            rng: Xoshiro256pp::new(seed),
            normal_cache: None,
            items_done: 0,
        }
    }

    /// Arbitrary phase schedule.
    pub fn phased(phases: Vec<(u64, Distribution)>, seed: u64) -> Self {
        assert!(!phases.is_empty());
        ServiceProcess { phases, rng: Xoshiro256pp::new(seed), normal_cache: None, items_done: 0 }
    }

    /// The distribution currently in effect.
    pub fn current(&self) -> &Distribution {
        let done = self.items_done;
        for (limit, d) in &self.phases {
            if done < *limit {
                return d;
            }
        }
        &self.phases.last().unwrap().1
    }

    /// Index of the phase currently in effect.
    pub fn phase_index(&self) -> usize {
        let done = self.items_done;
        for (i, (limit, _)) in self.phases.iter().enumerate() {
            if done < *limit {
                return i;
            }
        }
        self.phases.len() - 1
    }

    /// Draw the service time for the next item and advance the item count.
    #[inline]
    pub fn next_service_ns(&mut self) -> f64 {
        let done = self.items_done;
        self.items_done += 1;
        let mut dist = &self.phases.last().unwrap().1;
        for (limit, d) in &self.phases {
            if done < *limit {
                dist = d;
                break;
            }
        }
        dist.clone().sample(&mut self.rng, &mut self.normal_cache)
    }

    /// Items drawn so far.
    pub fn items_done(&self) -> u64 {
        self.items_done
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rate_round_trips() {
        let d = Distribution::from_rate_mbps(DistKind::Deterministic, 4.0, 8);
        assert!((d.rate_mbps(8) - 4.0).abs() < 1e-9);
        // 4 MB/s over 8-byte items = 500k items/s = 2000 ns/item.
        assert!((d.mean_ns() - 2000.0).abs() < 1e-9);
    }

    #[test]
    fn deterministic_is_constant() {
        let mut p = ServiceProcess::single(Distribution::Deterministic { mean_ns: 123.0 }, 1);
        for _ in 0..100 {
            assert_eq!(p.next_service_ns(), 123.0);
        }
    }

    #[test]
    fn exponential_sample_mean() {
        let mut p =
            ServiceProcess::single(Distribution::Exponential { mean_ns: 500.0 }, 99);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| p.next_service_ns()).sum::<f64>() / n as f64;
        assert!((mean - 500.0).abs() < 10.0, "mean = {mean}");
    }

    #[test]
    fn dual_phase_switches() {
        let a = Distribution::Deterministic { mean_ns: 100.0 };
        let b = Distribution::Deterministic { mean_ns: 900.0 };
        let mut p = ServiceProcess::dual(a, b, 50, 3);
        for i in 0..100 {
            let s = p.next_service_ns();
            if i < 50 {
                assert_eq!(s, 100.0, "item {i}");
                assert_eq!(p.phase_index(), if i < 49 { 0 } else { 1 });
            } else {
                assert_eq!(s, 900.0, "item {i}");
            }
        }
    }

    #[test]
    fn cv_matches_family() {
        assert_eq!(Distribution::Deterministic { mean_ns: 5.0 }.cv(), 0.0);
        assert_eq!(Distribution::Exponential { mean_ns: 5.0 }.cv(), 1.0);
        let u = Distribution::Uniform { lo_ns: 0.0, hi_ns: 10.0 };
        assert!((u.cv() - 1.0 / (3.0f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn normal_truncation_nonnegative() {
        let d = Distribution::Normal { mean_ns: 10.0, sd_ns: 50.0 };
        let mut rng = Xoshiro256pp::new(5);
        let mut cache = None;
        for _ in 0..10_000 {
            assert!(d.sample(&mut rng, &mut cache) >= 0.0);
        }
    }
}
