//! Pseudo-random numbers and service-process distributions.
//!
//! Substitute for the GSL random-number source the paper uses to drive its
//! micro-benchmarks (§V-A): a xoshiro256++ generator (public-domain
//! reference algorithm by Blackman & Vigna) seeded through SplitMix64, plus
//! the service-time distributions the paper evaluates — exponential (an
//! M/M/1-style service process) and deterministic (M/D/1-style) — and the
//! bimodal/dual-phase modulation used for the phase-detection experiments.

pub mod dist;

pub use dist::{Distribution, ServiceProcess};

/// xoshiro256++ 1.0 — fast, high-quality, 256-bit state.
#[derive(Debug, Clone)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

/// SplitMix64 — used only to expand a 64-bit seed into xoshiro state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Xoshiro256pp {
    /// Seed via SplitMix64 (any seed, including 0, yields a valid state).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Xoshiro256pp { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = rotl(s[0].wrapping_add(s[3]), 23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = rotl(s[3], 45);
        result
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in (0, 1] — safe as a log() argument.
    #[inline]
    pub fn next_f64_open(&mut self) -> f64 {
        1.0 - self.next_f64()
    }

    /// Uniform u32 in [0, bound) via Lemire's method.
    #[inline]
    pub fn next_bounded(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        let x = self.next_u64() as u32 as u64;
        ((x * bound as u64) >> 32) as u32
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller (both variates used alternately).
    pub fn standard_normal(&mut self, cache: &mut Option<f64>) -> f64 {
        if let Some(z) = cache.take() {
            return z;
        }
        let u1 = self.next_f64_open();
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        *cache = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Exponential with the given mean, via inverse CDF.
    #[inline]
    pub fn exponential(&mut self, mean: f64) -> f64 {
        -mean * self.next_f64_open().ln()
    }

    /// Jump function: advances the stream by 2^128 steps — used to hand
    /// non-overlapping substreams to independent kernels/threads.
    pub fn jump(&mut self) {
        const JUMP: [u64; 4] =
            [0x180EC6D33CFD0ABA, 0xD5A61266F0C9392C, 0xA9582618E03FC9AA, 0x39ABDC4529B1661C];
        let mut s = [0u64; 4];
        for j in JUMP {
            for b in 0..64 {
                if (j & (1u64 << b)) != 0 {
                    s[0] ^= self.s[0];
                    s[1] ^= self.s[1];
                    s[2] ^= self.s[2];
                    s[3] ^= self.s[3];
                }
                self.next_u64();
            }
        }
        self.s = s;
    }

    /// Split off an independent substream: the child continues the current
    /// sequence while `self` jumps 2^128 steps ahead — so parent and child
    /// never overlap (for < 2^128 draws each).
    pub fn split(&mut self) -> Self {
        let child = self.clone();
        self.jump();
        child
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Xoshiro256pp::new(42);
        let mut b = Xoshiro256pp::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Xoshiro256pp::new(1);
        let mut b = Xoshiro256pp::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Xoshiro256pp::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            let y = r.next_f64_open();
            assert!(y > 0.0 && y <= 1.0);
        }
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Xoshiro256pp::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean = {mean}");
    }

    #[test]
    fn exponential_mean_matches() {
        let mut r = Xoshiro256pp::new(13);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.exponential(3.0)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.05, "mean = {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro256pp::new(17);
        let mut cache = None;
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.standard_normal(&mut cache)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64;
        assert!(mean.abs() < 0.02, "mean = {mean}");
        assert!((var - 1.0).abs() < 0.03, "var = {var}");
    }

    #[test]
    fn bounded_is_bounded() {
        let mut r = Xoshiro256pp::new(19);
        for _ in 0..10_000 {
            assert!(r.next_bounded(17) < 17);
        }
    }

    #[test]
    fn jump_produces_disjoint_stream() {
        let mut a = Xoshiro256pp::new(23);
        let b0: Vec<u64> = {
            let mut b = a.split();
            (0..32).map(|_| b.next_u64()).collect()
        };
        let a0: Vec<u64> = (0..32).map(|_| a.next_u64()).collect();
        assert_ne!(a0, b0);
    }
}
