//! `artifacts/manifest.json` — the contract between `python/compile/aot.py`
//! and the Rust runtime. Shapes are validated at artifact-load and at every
//! execute, so drift between the layers fails loudly.

use std::path::Path;

use crate::config::json::Json;
use crate::{Result, SfError};

/// One tensor's shape/dtype as recorded by aot.py.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        let shape = j
            .get("shape")
            .and_then(|s| s.as_arr())
            .ok_or_else(|| SfError::Artifact("tensor spec missing 'shape'".into()))?
            .iter()
            .map(|d| {
                d.as_u64()
                    .map(|v| v as usize)
                    .ok_or_else(|| SfError::Artifact("non-integer dim".into()))
            })
            .collect::<Result<Vec<_>>>()?;
        let dtype = j
            .get("dtype")
            .and_then(|d| d.as_str())
            .ok_or_else(|| SfError::Artifact("tensor spec missing 'dtype'".into()))?
            .to_string();
        Ok(TensorSpec { shape, dtype })
    }
}

/// One artifact entry.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: u64,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Load and validate `manifest.json`.
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path).map_err(|e| {
            SfError::Artifact(format!(
                "cannot read {} (run `make artifacts` first?): {e}",
                path.display()
            ))
        })?;
        Self::parse(&text)
    }

    /// Parse from a JSON string.
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text)?;
        let version = j
            .get("version")
            .and_then(|v| v.as_u64())
            .ok_or_else(|| SfError::Artifact("manifest missing 'version'".into()))?;
        if version != 1 {
            return Err(SfError::Artifact(format!("unsupported manifest version {version}")));
        }
        let arts = j
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| SfError::Artifact("manifest missing 'artifacts'".into()))?;
        let mut artifacts = Vec::with_capacity(arts.len());
        for a in arts {
            let name = a
                .get("name")
                .and_then(|n| n.as_str())
                .ok_or_else(|| SfError::Artifact("artifact missing 'name'".into()))?
                .to_string();
            let file = a
                .get("file")
                .and_then(|f| f.as_str())
                .ok_or_else(|| SfError::Artifact(format!("artifact '{name}' missing 'file'")))?
                .to_string();
            let inputs = a
                .get("inputs")
                .and_then(|i| i.as_arr())
                .ok_or_else(|| SfError::Artifact(format!("artifact '{name}' missing inputs")))?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = a
                .get("outputs")
                .and_then(|o| o.as_arr())
                .ok_or_else(|| SfError::Artifact(format!("artifact '{name}' missing outputs")))?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            artifacts.push(ArtifactSpec { name, file, inputs, outputs });
        }
        Ok(Manifest { version, artifacts })
    }

    /// Lookup by name.
    pub fn get(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// All artifact names.
    pub fn names(&self) -> Vec<&str> {
        self.artifacts.iter().map(|a| a.name.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "artifacts": [
        {"name": "estimator_b1_w64", "file": "estimator_b1_w64.hlo.txt",
         "inputs": [{"shape": [1, 64], "dtype": "float32"}],
         "outputs": [{"shape": [1], "dtype": "float32"},
                      {"shape": [1], "dtype": "float32"},
                      {"shape": [1], "dtype": "float32"}]}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.version, 1);
        let a = m.get("estimator_b1_w64").unwrap();
        assert_eq!(a.inputs[0].shape, vec![1, 64]);
        assert_eq!(a.inputs[0].elements(), 64);
        assert_eq!(a.outputs.len(), 3);
        assert_eq!(m.names(), vec!["estimator_b1_w64"]);
    }

    #[test]
    fn unknown_name_is_none() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.get("nope").is_none());
    }

    #[test]
    fn rejects_bad_version() {
        let m = Manifest::parse(r#"{"version": 2, "artifacts": []}"#);
        assert!(m.is_err());
    }

    #[test]
    fn rejects_missing_fields() {
        assert!(Manifest::parse(r#"{"artifacts": []}"#).is_err());
        assert!(Manifest::parse(r#"{"version":1,"artifacts":[{"name":"x"}]}"#).is_err());
    }
}
