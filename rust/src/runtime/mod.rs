//! PJRT runtime: load and execute AOT-compiled HLO artifacts.
//!
//! The build-time Python stack (`python/compile/`) lowers the L2 JAX graphs
//! (which call the L1 Pallas kernels) to **HLO text** under `artifacts/`,
//! described by `manifest.json`. With the `pjrt` cargo feature enabled this
//! module wraps the `xla` crate: text → `HloModuleProto` → compile once on
//! the CPU PJRT client → execute from the Rust hot path. Python never runs
//! at request time.
//!
//! **Feature gating:** the default build carries no accelerator toolchain —
//! [`Engine::load_dir`] then returns a readable [`SfError::Artifact`] and
//! every consumer (the monitor's XLA backend, the matmul XLA dot kernel,
//! the ablation bench) falls back to the native path. Manifest parsing,
//! [`ThreadBound`], and [`default_artifact_dir`] are always available.

pub mod manifest;

pub use manifest::{ArtifactSpec, Manifest, TensorSpec};

use std::path::PathBuf;

use crate::Result;

#[cfg(feature = "pjrt")]
mod engine {
    use std::path::{Path, PathBuf};
    use std::rc::Rc;

    use super::manifest::{ArtifactSpec, Manifest};
    use crate::{Result, SfError};

    /// A PJRT client plus the artifact manifest of a directory.
    pub struct Engine {
        client: Rc<xla::PjRtClient>,
        manifest: Manifest,
        dir: PathBuf,
    }

    impl Engine {
        /// Load `manifest.json` from `dir` and bring up the CPU PJRT client.
        pub fn load_dir(dir: &Path) -> Result<Engine> {
            let manifest = Manifest::load(&dir.join("manifest.json"))?;
            let client = xla::PjRtClient::cpu()?;
            Ok(Engine { client: Rc::new(client), manifest, dir: dir.to_path_buf() })
        }

        /// Platform string (e.g. "cpu") for reports.
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// The manifest read at load time.
        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        /// Compile one artifact by manifest name.
        pub fn load_artifact(&self, name: &str) -> Result<ArtifactExec> {
            let spec = self
                .manifest
                .get(name)
                .ok_or_else(|| SfError::Artifact(format!("artifact '{name}' not in manifest")))?
                .clone();
            let path = self.dir.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(&path)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            Ok(ArtifactExec { exe, spec, _client: self.client.clone() })
        }
    }

    /// A compiled artifact ready to execute.
    pub struct ArtifactExec {
        exe: xla::PjRtLoadedExecutable,
        spec: ArtifactSpec,
        /// Keep the client alive as long as the executable.
        _client: Rc<xla::PjRtClient>,
    }

    impl ArtifactExec {
        /// The manifest entry this was compiled from.
        pub fn spec(&self) -> &ArtifactSpec {
            &self.spec
        }

        /// Execute with f32 inputs `(data, dims)`; returns flattened f32
        /// outputs in manifest order.
        ///
        /// Validates shapes against the manifest before touching PJRT so a
        /// mismatched artifact fails with a readable error instead of an
        /// XLA abort.
        pub fn run_f32(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
            if inputs.len() != self.spec.inputs.len() {
                return Err(SfError::Artifact(format!(
                    "artifact '{}' expects {} inputs, got {}",
                    self.spec.name,
                    self.spec.inputs.len(),
                    inputs.len()
                )));
            }
            let mut literals = Vec::with_capacity(inputs.len());
            for (idx, ((data, dims), spec)) in inputs.iter().zip(&self.spec.inputs).enumerate() {
                let want: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
                if *dims != want.as_slice() {
                    return Err(SfError::Artifact(format!(
                        "artifact '{}' input {idx}: shape {:?} != manifest {:?}",
                        self.spec.name, dims, want
                    )));
                }
                let expect_len: i64 = dims.iter().product();
                if data.len() as i64 != expect_len {
                    return Err(SfError::Artifact(format!(
                        "artifact '{}' input {idx}: {} elements for shape {:?}",
                        self.spec.name,
                        data.len(),
                        dims
                    )));
                }
                literals.push(xla::Literal::vec1(data).reshape(dims)?);
            }
            let result = self.exe.execute::<xla::Literal>(&literals)?;
            let first = result
                .first()
                .and_then(|r| r.first())
                .ok_or_else(|| SfError::Artifact("empty execution result".into()))?;
            let lit = first.to_literal_sync()?;
            // aot.py lowers with return_tuple=True: the result is a tuple.
            let parts = lit.to_tuple()?;
            let mut outs = Vec::with_capacity(parts.len());
            for p in parts {
                outs.push(p.to_vec::<f32>()?);
            }
            if outs.len() != self.spec.outputs.len() {
                return Err(SfError::Artifact(format!(
                    "artifact '{}' returned {} outputs, manifest says {}",
                    self.spec.name,
                    outs.len(),
                    self.spec.outputs.len()
                )));
            }
            Ok(outs)
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod engine {
    use std::path::{Path, PathBuf};

    use super::manifest::{ArtifactSpec, Manifest};
    use crate::{Result, SfError};

    /// Stub engine for builds without the `pjrt` feature. Loading always
    /// fails with a readable error so callers take their native fallback.
    pub struct Engine {
        manifest: Manifest,
        dir: PathBuf,
    }

    impl Engine {
        /// Parse the manifest (so missing-directory errors look identical
        /// to the real engine's), then report the runtime as unavailable.
        pub fn load_dir(dir: &Path) -> Result<Engine> {
            let probe = Engine {
                manifest: Manifest::load(&dir.join("manifest.json"))?,
                dir: dir.to_path_buf(),
            };
            Err(SfError::Artifact(format!(
                "artifact directory '{}' is readable ({} artifacts), but this build \
                 has no PJRT runtime — add an `xla` bindings dependency (see the \
                 comment in rust/Cargo.toml) and rebuild with `--features pjrt`",
                probe.dir.display(),
                probe.manifest().names().len()
            )))
        }

        /// Platform string (e.g. "cpu") for reports.
        pub fn platform(&self) -> String {
            "unavailable (pjrt feature disabled)".to_string()
        }

        /// The manifest read at load time.
        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        /// Compile one artifact by manifest name.
        pub fn load_artifact(&self, name: &str) -> Result<ArtifactExec> {
            Err(SfError::Artifact(format!(
                "cannot compile artifact '{name}': built without the `pjrt` feature \
                 (requires a vendored `xla` crate — see rust/Cargo.toml)"
            )))
        }
    }

    /// Stub compiled artifact; never constructed without `pjrt`.
    pub struct ArtifactExec {
        spec: ArtifactSpec,
    }

    impl ArtifactExec {
        /// The manifest entry this was compiled from.
        pub fn spec(&self) -> &ArtifactSpec {
            &self.spec
        }

        /// Execute with f32 inputs `(data, dims)`.
        pub fn run_f32(&self, _inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
            Err(SfError::Artifact(format!(
                "cannot execute artifact '{}': built without the `pjrt` feature",
                self.spec.name
            )))
        }
    }
}

pub use engine::{ArtifactExec, Engine};

/// A cell for PJRT objects that must live entirely on one thread.
///
/// The `xla` crate's client/executable types are `!Send` (raw PJRT
/// pointers). Kernels, however, are moved onto their scheduler thread
/// *before* running. `ThreadBound` lets a kernel struct cross the spawn
/// boundary **empty** and lazily create the PJRT object on its own thread:
/// the value is only ever created, used, and dropped on the thread that
/// first initialized it (checked at runtime).
pub struct ThreadBound<T> {
    inner: Option<T>,
    owner: Option<std::thread::ThreadId>,
}

// SAFETY: `inner` is None whenever the value crosses threads (enforced by
// the owner check on every access and on drop), so the !Send payload never
// actually migrates.
unsafe impl<T> Send for ThreadBound<T> {}

impl<T> Default for ThreadBound<T> {
    fn default() -> Self {
        ThreadBound { inner: None, owner: None }
    }
}

impl<T> ThreadBound<T> {
    /// An empty (sendable) cell.
    pub fn empty() -> Self {
        Self::default()
    }

    /// Get the value, initializing it on the current thread on first use.
    /// Panics if accessed from a different thread than the initializer.
    pub fn get_or_try_init(
        &mut self,
        f: impl FnOnce() -> Result<T>,
    ) -> Result<&mut T> {
        let me = std::thread::current().id();
        match self.owner {
            None => {
                self.inner = Some(f()?);
                self.owner = Some(me);
            }
            Some(owner) => {
                assert_eq!(owner, me, "ThreadBound accessed from a foreign thread");
            }
        }
        Ok(self.inner.as_mut().expect("just initialized"))
    }

    /// True once initialized.
    pub fn is_init(&self) -> bool {
        self.inner.is_some()
    }
}

impl<T> Drop for ThreadBound<T> {
    fn drop(&mut self) {
        if let (Some(owner), true) = (self.owner, self.inner.is_some()) {
            assert_eq!(
                owner,
                std::thread::current().id(),
                "ThreadBound with live value dropped on a foreign thread"
            );
        }
    }
}

/// Default artifact directory: `$SF_ARTIFACTS`, else the first of
/// `./artifacts` and `../artifacts` that holds a manifest (cargo runs
/// tests/benches from the package dir, binaries usually from the
/// workspace root — support both).
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(d) = std::env::var("SF_ARTIFACTS") {
        return PathBuf::from(d);
    }
    for cand in ["artifacts", "../artifacts"] {
        let p = PathBuf::from(cand);
        if p.join("manifest.json").exists() {
            return p;
        }
    }
    PathBuf::from("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    /// Integration coverage lives in `rust/tests/runtime_artifacts.rs`
    /// (needs `make artifacts` to have run). Here: pure failure paths.
    #[test]
    fn missing_dir_is_artifact_error() {
        let e = match Engine::load_dir(Path::new("/nonexistent/sf_test")) {
            Err(e) => e,
            Ok(_) => panic!("expected error for missing dir"),
        };
        match e {
            crate::SfError::Artifact(_) | crate::SfError::Io(_) => {}
            other => panic!("unexpected error: {other:?}"),
        }
    }
}
