//! Thread-per-kernel scheduler + run lifecycle.
//!
//! Mirrors the paper's execution model (Fig. 5): every compute kernel and
//! every queue monitor executes on an independent thread, subject to the
//! runtime and the OS scheduler. `run()` drives the whole application to
//! completion and returns a [`RunReport`] with the converged service-rate
//! estimates per stream.
//!
//! When the topology declares replicable stages
//! ([`crate::topology::Topology::add_elastic_stage`]) the scheduler also
//! spawns the [`ElasticController`] control-plane thread: it takes over
//! the monitor-event channel (absorbing and forwarding every event), and
//! its audited actions land in [`RunReport::elastic_events`]. Replica
//! worker threads are managed by their stages and joined here after the
//! graph's own kernels finish — thread lifecycle is dynamic, not the old
//! fixed spawn-all.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::elastic::{
    ElasticConfig, ElasticController, ElasticEvent, FaultRecord, ShedBinding,
    StageBinding, StageFaultLog, StageTrajectory, StreamBinding,
};
use crate::error::panic_message;
use crate::estimator::RateEstimate;
use crate::kernel::{KernelContext, KernelStatus};
use crate::monitor::{MonitorConfig, MonitorEvent, QueueEnd, QueueMonitor};
use crate::placement::{
    partition_cpus, CpuTopology, PlacementAssignment, PlacementPolicy, PlacementReport,
    ThreadPin,
};
use crate::telemetry::{
    ControlEvent, EventRing, JsonlTail, MetricsRegistry, MetricsServer, MetricsShared,
    TelemetryConfig,
};
use crate::timing::TimeRef;
use crate::topology::{StreamId, Topology};
use crate::{Result, SfError};

/// Everything a run produced.
#[derive(Debug, Default)]
pub struct RunReport {
    /// Wall-clock of the kernel phase (ns).
    pub wall_ns: u64,
    /// Converged estimates per (stream, end).
    pub estimates: Vec<(StreamId, QueueEnd, RateEstimate)>,
    /// Best-effort (unconverged) estimates emitted at shutdown.
    pub best_effort: Vec<(StreamId, QueueEnd, RateEstimate)>,
    /// Period-change events per stream.
    pub period_events: Vec<(StreamId, u64)>,
    /// Raw taps (when `raw_tap` is configured).
    pub raw_samples: Vec<MonitorEvent>,
    /// Failure events (paper: "when the heuristic fails, it usually fails
    /// knowingly").
    pub failures: Vec<(StreamId, String)>,
    /// §VII classifications emitted alongside converged estimates.
    pub classifications: Vec<(StreamId, QueueEnd, crate::classify::DistributionClass)>,
    /// Lifetime totals per stream label: (pushes, pops).
    pub stream_totals: HashMap<String, (u64, u64)>,
    /// Audit trail of every control-plane action (replication + resizes).
    pub elastic_events: Vec<ElasticEvent>,
    /// Per-stream blocked-duration fractions of the kernel-phase wall
    /// clock: how much of the run each stream's consumer lost to
    /// starvation (`read_frac`) and its producer to backpressure
    /// (`write_frac`).
    pub stream_blocked: Vec<StreamBlocked>,
    /// Per-stage replica counts over the run (initial point + one point
    /// per scaling action) — the scaling timeline of an elastic run.
    pub replica_trajectories: Vec<StageTrajectory>,
    /// The effective global worker budget over the run: one
    /// `(at_ns, budget)` point per change. Non-empty only when the
    /// controller ran with a capping
    /// [`BudgetPolicy`](crate::placement::BudgetPolicy); a
    /// host-aware run shows the budget following host load here.
    pub budget_timeline: Vec<(u64, usize)>,
    /// Core-affinity placement outcome: per-stage cpu assignments with
    /// pinned/denied thread counts, plus explicit no-op/degradation
    /// annotations (missing topology files, refused `sched_setaffinity`,
    /// unreadable host load).
    pub placement: PlacementReport,
    /// The full structured control-plane journal (superset of
    /// `elastic_events`): lane spawns/retires, gate reasons, budget
    /// changes, blocked spans, converged rates. Feeds
    /// [`RunReport::write_chrome_trace`] and the JSONL tail.
    pub control_events: Vec<ControlEvent>,
    /// Control-plane events lost to event-ring overflow. Non-zero only
    /// when one control tick emitted more events than the ring transport
    /// holds — audited here and as `sf_events_dropped_total`, never
    /// silently truncated.
    pub events_dropped: u64,
    /// Supervision faults captured during the run, in timestamp order:
    /// lane panics (with restart/escalation state), kernel-thread panics,
    /// and the deadline abort. Empty on a healthy run.
    pub faults: Vec<FaultRecord>,
    /// Items audited as lost to faults: panicked mid-process, drained by
    /// an escalated lane, or stranded in a poisoned stream. Conservation
    /// holds as `items delivered + items_lost (+ items_shed at the
    /// source) == items offered` — loss is always explicit, never silent.
    pub items_lost: u64,
    /// Items deliberately dropped by degraded (shedding) sources — the
    /// other audited term of the conservation equation.
    pub items_shed: u64,
    /// Highest degradation level in force at the end of the run
    /// (0 = full fidelity).
    pub shed_level: u8,
    /// The pre-run static analysis report
    /// ([`GraphAnalyzer`](crate::analysis::GraphAnalyzer)). A run that
    /// reaches a report at all passed with no errors, so only warnings
    /// (e.g. rule A5 monitor-validity notes) appear here; they are also
    /// mirrored into `control_events` as `ControlEvent::Note`s and the
    /// `sf_analysis_warnings` gauge.
    pub analysis: crate::analysis::AnalysisReport,
    /// The run was force-terminated by [`RunOptions::deadline`]
    /// (crate::flow::RunOptions::deadline) before the topology drained;
    /// every total in this report describes the partial run.
    pub deadline_hit: bool,
}

/// Fraction of a run one stream spent blocked, per end.
#[derive(Debug, Clone)]
pub struct StreamBlocked {
    /// Stream label ("kernelA.port -> kernelB.port").
    pub label: String,
    /// Consumer blocked-on-empty time / wall time (starvation).
    pub read_frac: f64,
    /// Producer blocked-on-full time / wall time (backpressure).
    pub write_frac: f64,
}

impl RunReport {
    /// Converged head-end (service-rate) estimates for one stream.
    pub fn rates_for(&self, stream: StreamId) -> Vec<&RateEstimate> {
        self.estimates
            .iter()
            .filter(|(s, e, _)| *s == stream && *e == QueueEnd::Head)
            .map(|(_, _, r)| r)
            .collect()
    }

    /// Latest converged head estimate for a stream (the "current" rate).
    pub fn latest_rate(&self, stream: StreamId) -> Option<&RateEstimate> {
        self.rates_for(stream).into_iter().last()
    }

    /// All converged estimates for an end across streams.
    pub fn all_rates(&self, end: QueueEnd) -> Vec<(StreamId, &RateEstimate)> {
        self.estimates
            .iter()
            .filter(|(_, e, _)| *e == end)
            .map(|(s, _, r)| (*s, r))
            .collect()
    }

    /// Wall-clock seconds.
    pub fn wall_secs(&self) -> f64 {
        self.wall_ns as f64 / 1.0e9
    }

    /// Replication actions (scale-up/down) in the audit trail.
    pub fn scale_actions(&self) -> usize {
        self.elastic_events.iter().filter(|e| e.is_scale()).count()
    }

    /// Blocked fractions for one stream by label, if recorded.
    pub fn blocked_for(&self, label: &str) -> Option<&StreamBlocked> {
        self.stream_blocked.iter().find(|b| b.label == label)
    }

    /// Human-readable scaling timeline: one line per stage trajectory,
    /// then the audited control actions in order — what an app run prints
    /// to show how the control plane behaved.
    pub fn scaling_timeline(&self) -> Vec<String> {
        let mut lines = Vec::new();
        for tr in &self.replica_trajectories {
            let path = tr
                .points
                .iter()
                .map(|(t, r)| format!("{r}@{:.3}s", *t as f64 / 1.0e9))
                .collect::<Vec<_>>()
                .join(" -> ");
            lines.push(format!("stage {}: replicas {path}", tr.stage));
        }
        if !self.budget_timeline.is_empty() {
            let path = self
                .budget_timeline
                .iter()
                .map(|(t, b)| format!("{b}@{:.3}s", *t as f64 / 1.0e9))
                .collect::<Vec<_>>()
                .join(" -> ");
            lines.push(format!("worker budget: {path}"));
        }
        for a in &self.placement.assignments {
            let note = match &a.note {
                Some(n) => format!("; {n}"),
                None => String::new(),
            };
            lines.push(format!(
                "placement {}: cpus {:?} ({} pinned, {} denied{note})",
                a.target, a.cpus, a.pinned_threads, a.denied_threads
            ));
        }
        for n in &self.placement.notes {
            lines.push(format!("placement note: {n}"));
        }
        for ev in &self.elastic_events {
            lines.push(ev.to_string());
        }
        lines
    }

    /// Serialize the run's control-plane history — lane lifetimes,
    /// replica/budget counters, blocked spans, scale/resize/gate
    /// instants — as a Perfetto / `chrome://tracing` JSON file. Open it
    /// at <https://ui.perfetto.dev>.
    pub fn write_chrome_trace<P: AsRef<std::path::Path>>(&self, path: P) -> Result<()> {
        crate::telemetry::chrome::write_trace(self, path.as_ref())
    }
}

/// The run engine behind [`crate::flow::Session::run`]: spawn kernels +
/// monitors (+ the elastic controller), join, aggregate. Consumes the
/// topology's kernel table; stream metadata survives for the report.
///
/// (The pre-0.4 `Scheduler::with_monitoring(..).with_elastic(..)` shim
/// surface is gone — [`crate::flow::RunOptions`] is the one way to
/// configure a run.)
#[allow(clippy::too_many_arguments)]
pub(crate) fn execute(
    topo: &mut Topology,
    monitor_cfg: &MonitorConfig,
    elastic_cfg: &ElasticConfig,
    elastic_forced: bool,
    placement: PlacementPolicy,
    telemetry: &TelemetryConfig,
    deadline: Option<Duration>,
    shedders: Vec<ShedBinding>,
) -> Result<RunReport> {
    topo.validate()?;
    // Pre-run static analysis: a structurally-doomed graph (bounded-queue
    // cycle, unreachable kernel, infeasible budget) aborts here, before a
    // single kernel thread spawns, with the full report attached.
    // Warnings survive into the report/journal/gauge below.
    let analysis_ctx = crate::analysis::AnalysisContext {
        elastic: (elastic_forced || !topo.elastic.is_empty()).then_some(elastic_cfg),
        net_plan: &[],
    };
    let analysis = crate::analysis::GraphAnalyzer::new().analyze(topo, &analysis_ctx);
    if analysis.has_errors() {
        return Err(SfError::Analysis(Box::new(analysis)));
    }
    let time = TimeRef::new();

    // ---- elastic control-plane bindings (resolved before the kernel
    // table is consumed) -----------------------------------------------
    let mut stage_bindings: Vec<StageBinding> = Vec::new();
    for decl in &topo.elastic {
        let bind = |e: &crate::topology::StreamEdge| StreamBinding {
            id: e.id,
            label: e.label.clone(),
            handle: e.monitor.clone(),
        };
        let upstream = topo.streams.iter().find(|e| e.dst == decl.split).map(bind);
        let downstream = topo.streams.iter().find(|e| e.src == decl.merge).map(bind);
        stage_bindings.push(StageBinding { stage: decl.stage.clone(), upstream, downstream });
    }
    // ---- placement: pack each stage onto co-located cores ------------
    // Pins are installed on the stages (covering lane workers present
    // and future) and remembered per split/merge kernel id for the spawn
    // loop below. Every failure mode — no stages, unreadable topology,
    // denied syscalls — degrades to a recorded no-op in the report.
    let mut stage_pins: Vec<(String, Arc<ThreadPin>, Option<usize>)> = Vec::new();
    let mut kernel_pins: HashMap<usize, Arc<ThreadPin>> = HashMap::new();
    let mut placement_notes: Vec<String> = Vec::new();
    if placement == PlacementPolicy::Pack {
        if topo.elastic.is_empty() {
            placement_notes
                .push("placement: no replicable stages — nothing to pin (no-op)".into());
        } else {
            let host = CpuTopology::discover();
            if let Some(reason) = host.fallback_reason() {
                placement_notes.push(format!(
                    "placement: cpu topology unreadable ({reason}); packing over a flat \
                     cpu list"
                ));
            }
            // First-touch NUMA audit: lane queues are prefaulted by their
            // (pinned) workers, so each stage's cpu chunk decides where
            // its segments land. Degraded node ids must say so — a run
            // report claiming "node 0" on a masked-node container would
            // otherwise be a silent lie.
            let numa_degraded = host.numa_fallback_reason().is_some();
            if let Some(reason) = host.numa_fallback_reason() {
                placement_notes.push(format!("placement: numa fallback — {reason}"));
            }
            let order = host.pack_order();
            let weights: Vec<usize> = topo
                .elastic
                .iter()
                .map(|d| d.stage.policy().max_replicas.max(1))
                .collect();
            for (decl, cpus) in topo.elastic.iter().zip(partition_cpus(&order, &weights)) {
                let nodes = host.nodes_of(&cpus);
                let numa_node = match (numa_degraded, nodes.as_slice()) {
                    (false, [node]) => Some(*node),
                    _ => None,
                };
                match (numa_degraded, nodes.as_slice()) {
                    (true, _) => {} // global fallback note already covers it
                    (false, [node]) => placement_notes.push(format!(
                        "placement: stage '{}' lane queues first-touch on numa node \
                         {node} (cpus {cpus:?})",
                        decl.stage.stage_name()
                    )),
                    (false, nodes) => placement_notes.push(format!(
                        "placement: stage '{}' cpu set spans numa nodes {nodes:?}; lane \
                         queues first-touch per-worker",
                        decl.stage.stage_name()
                    )),
                }
                let pin = ThreadPin::new(cpus);
                decl.stage.install_pin(pin.clone());
                kernel_pins.insert(decl.split.0, pin.clone());
                kernel_pins.insert(decl.merge.0, pin.clone());
                stage_pins.push((decl.stage.stage_name().to_string(), pin, numa_node));
            }
        }
    }

    let use_controller = !stage_bindings.is_empty() || elastic_forced;
    let stream_bindings: Vec<StreamBinding> = if use_controller {
        topo.streams
            .iter()
            .map(|e| StreamBinding {
                id: e.id,
                label: e.label.clone(),
                handle: e.monitor.clone(),
            })
            .collect()
    } else {
        Vec::new()
    };

    // ---- telemetry plane (inert unless RunOptions opted in) ----------
    // Ring + gauge block + registry over the streams/stages resolved
    // above; the registry's scrape reads are the already-free lifetime
    // counters, so the data path is untouched.
    let tel_active = telemetry.is_active();
    let tel_ring = tel_active
        .then(|| Arc::new(EventRing::new(telemetry.effective_ring_capacity())));
    let tel_shared = tel_active.then(|| MetricsShared::new(topo.elastic.len()));
    if let Some(shared) = &tel_shared {
        // The analyzer ran before spawn; its warning count is a static
        // property of this run, so the gauge is live from the first scrape.
        shared.set_analysis_warnings(analysis.warnings().count() as u64);
    }
    let tel_registry = match (&tel_ring, &tel_shared) {
        (Some(ring), Some(shared)) => {
            let mut reg = MetricsRegistry::new(shared.clone());
            for edge in topo.streams.iter() {
                reg.add_stream(edge.id, edge.label.clone(), edge.monitor.clone());
            }
            for decl in &topo.elastic {
                reg.add_stage(decl.stage.clone());
            }
            for stats in topo.net_edges.iter() {
                reg.add_net_edge(stats.clone());
            }
            reg.set_ring(ring.clone());
            Some(Arc::new(reg))
        }
        _ => None,
    };
    let metrics_server = match (&telemetry.metrics_addr, &tel_registry) {
        (Some(addr), Some(reg)) => {
            let srv = MetricsServer::spawn(addr, reg.clone())?;
            if let Some(cell) = &telemetry.bound {
                let _ = cell.set(srv.local_addr());
            }
            Some(srv)
        }
        _ => None,
    };
    let jsonl_tail = match (&telemetry.jsonl_path, &tel_ring) {
        (Some(path), Some(ring)) => Some(JsonlTail::spawn(path, ring.clone())?),
        _ => None,
    };

    // ---- panic isolation plumbing ------------------------------------
    // Per-kernel stream handles so a panicking kernel thread can poison
    // every edge it touches on its way down — peers parked on those
    // queues unpark into the terminal state instead of hanging — plus a
    // run-level fault sink for the structured panic records.
    let mut input_handles: HashMap<usize, Vec<Arc<dyn crate::queue::MonitorHandle>>> =
        HashMap::new();
    let mut output_handles: HashMap<usize, Vec<Arc<dyn crate::queue::MonitorHandle>>> =
        HashMap::new();
    for e in topo.streams.iter() {
        input_handles.entry(e.dst.0).or_default().push(e.monitor.clone());
        output_handles.entry(e.src.0).or_default().push(e.monitor.clone());
    }
    let run_faults = Arc::new(StageFaultLog::new());

    // ---- assemble per-kernel contexts --------------------------------
    let mut kernel_threads = Vec::new();
    let mut closers: Vec<Vec<Box<dyn crate::port::PortCloser>>> = Vec::new();
    let mut contexts: Vec<KernelContext> = Vec::new();
    let mut kernels = Vec::new();
    for node in topo.kernels.drain(..) {
        let mut inputs = node.inputs;
        inputs.sort_by_key(|(i, _)| *i);
        let mut outputs = node.outputs;
        outputs.sort_by_key(|(i, _, _)| *i);
        let mut kernel_closers = Vec::new();
        let mut outs = Vec::new();
        for (_, port, closer) in outputs {
            outs.push(port);
            kernel_closers.push(closer);
        }
        contexts.push(KernelContext::new(
            inputs.into_iter().map(|(_, p)| p).collect(),
            outs,
        ));
        closers.push(kernel_closers);
        kernels.push(node.kernel);
    }

    // ---- monitors -----------------------------------------------------
    let stop = Arc::new(AtomicBool::new(false));
    let (tx, rx) = channel::<MonitorEvent>();
    let mut monitor_threads = Vec::new();
    // Single-owner capacity rule: when the elastic controller manages
    // the monitored streams (buffer advice on), the monitors' own §III
    // resize trick is retired so exactly one loop touches capacity —
    // previously both mutated it independently.
    let mut per_stream_cfg = monitor_cfg.clone();
    if use_controller && elastic_cfg.buffer_advice {
        per_stream_cfg.resize_factor = 1.0;
    }
    if monitor_cfg.enabled {
        for edge in topo.streams.iter().filter(|e| e.config.instrument) {
            let m = QueueMonitor::new(
                edge.id,
                edge.monitor.clone(),
                per_stream_cfg.clone(),
                tx.clone(),
                stop.clone(),
            );
            monitor_threads.push(
                std::thread::Builder::new()
                    .name(format!("sf-mon-{}", edge.id.0))
                    .spawn(move || m.run())
                    .map_err(|e| SfError::Scheduler(e.to_string()))?,
            );
        }
    }
    drop(tx);

    // ---- elastic controller ------------------------------------------
    // It owns `rx` for the run, forwarding every event into `fwd` so
    // the end-of-run aggregation below is unchanged. A dedicated stop
    // flag is set only after the monitors have been joined, so the
    // controller always sees (and forwards) their final events.
    let ctl_stop = Arc::new(AtomicBool::new(false));
    let (ctl_thread, drain_rx) = if use_controller {
        let (fwd_tx, fwd_rx) = channel::<MonitorEvent>();
        let mut ctl = ElasticController::new(
            elastic_cfg.clone(),
            stage_bindings,
            stream_bindings,
            fwd_tx,
            ctl_stop.clone(),
        );
        if let (Some(ring), Some(shared)) = (&tel_ring, &tel_shared) {
            ctl.attach_telemetry(ring.clone(), shared.clone());
        }
        if !shedders.is_empty() {
            ctl.attach_shedders(shedders.clone());
        }
        let t = std::thread::Builder::new()
            .name("sf-elastic".into())
            .spawn(move || ctl.run(rx))
            .map_err(|e| SfError::Scheduler(e.to_string()))?;
        (Some(t), fwd_rx)
    } else {
        (None, rx)
    };

    // ---- kernels ------------------------------------------------------
    let t0 = time.now_ns();
    for (idx, ((mut kernel, mut ctx), kernel_closers)) in
        kernels.into_iter().zip(contexts).zip(closers).enumerate()
    {
        let name = kernel.name().to_string();
        // A stage's Split/Merge kernels share their lanes' cpu set, so
        // the whole stage stays co-located.
        let pin = kernel_pins.get(&idx).cloned();
        let in_handles = input_handles.get(&idx).cloned().unwrap_or_default();
        let out_handles = output_handles.get(&idx).cloned().unwrap_or_default();
        let fault_sink = run_faults.clone();
        let fault_name = name.clone();
        kernel_threads.push(
            std::thread::Builder::new()
                .name(format!("sf-k-{name}"))
                .spawn(move || {
                    if let Some(p) = &pin {
                        p.pin_self();
                    }
                    let outcome =
                        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                            kernel.on_start(&mut ctx);
                            loop {
                                match kernel.run(&mut ctx) {
                                    KernelStatus::Continue => {}
                                    KernelStatus::Stall => std::thread::yield_now(),
                                    KernelStatus::Done => break,
                                }
                            }
                            kernel.on_stop(&mut ctx);
                        }));
                    if let Err(payload) = outcome {
                        // Panic isolation: poison every stream this
                        // kernel touches so parked peers unpark into a
                        // terminal verdict instead of hanging, and turn
                        // the payload into a structured fault record.
                        // Items stranded in the poisoned queues are
                        // audited at report time (pushes − pops).
                        for h in in_handles.iter().chain(out_handles.iter()) {
                            h.poison();
                        }
                        fault_sink.record(FaultRecord {
                            at_ns: TimeRef::new().now_ns(),
                            target: fault_name,
                            lane: None,
                            restarts: 0,
                            escalated: true,
                            message: panic_message(payload.as_ref()),
                        });
                    }
                    // Close downstream streams so consumers terminate
                    // (idempotent after a poison on the panic path).
                    for c in &kernel_closers {
                        c.close_port();
                    }
                })
                .map_err(|e| SfError::Scheduler(e.to_string()))?,
        );
    }

    // ---- join the compute phase --------------------------------------
    // Without a deadline this is a plain join (kernel panics are caught
    // inside the threads above, so a join error here is exceptional).
    // With a deadline we poll instead: on expiry every stream edge is
    // poisoned and the elastic stages abort, unparking whatever is
    // blocked; threads that still refuse to exit (wedged outside queue
    // waits) are detached after a short grace rather than hanging the
    // session — the report comes back partial, with the abort audited.
    let mut deadline_hit = false;
    match deadline {
        None => {
            for t in kernel_threads {
                t.join().map_err(|payload| {
                    SfError::Scheduler(format!(
                        "kernel thread panicked: {}",
                        panic_message(payload.as_ref())
                    ))
                })?;
            }
        }
        Some(limit) => {
            let expiry = Instant::now() + limit;
            let mut pending = kernel_threads;
            while !pending.is_empty() && Instant::now() < expiry {
                pending.retain(|t| !t.is_finished());
                if pending.is_empty() {
                    break;
                }
                std::thread::sleep(Duration::from_millis(1));
            }
            pending.retain(|t| !t.is_finished());
            if !pending.is_empty() {
                deadline_hit = true;
                for edge in topo.streams.iter() {
                    edge.monitor.poison();
                }
                for decl in &topo.elastic {
                    decl.stage.abort();
                }
                run_faults.record(FaultRecord {
                    at_ns: time.now_ns(),
                    target: "session".into(),
                    lane: None,
                    restarts: 0,
                    escalated: true,
                    message: format!("deadline {limit:?} exceeded; topology force-closed"),
                });
                let grace = Instant::now() + Duration::from_millis(500);
                while !pending.is_empty() && Instant::now() < grace {
                    pending.retain(|t| !t.is_finished());
                    std::thread::sleep(Duration::from_millis(2));
                }
                // Whatever remains is stuck somewhere the poison cannot
                // reach (e.g. sleeping inside a kernel body): detach.
                drop(pending);
            }
        }
    }
    // Replica workers exit once their stage's splitter closed; join
    // them before declaring the compute phase over.
    for decl in &topo.elastic {
        decl.stage.join_workers();
    }
    let wall_ns = time.now_ns() - t0;

    // ---- stop monitors, then the controller, drain events ------------
    stop.store(true, Ordering::Relaxed);
    for t in monitor_threads {
        t.join().map_err(|payload| {
            SfError::Scheduler(format!(
                "monitor thread panicked: {}",
                panic_message(payload.as_ref())
            ))
        })?;
    }
    ctl_stop.store(true, Ordering::Relaxed);
    #[allow(clippy::type_complexity)]
    let (
        elastic_events,
        replica_trajectories,
        budget_timeline,
        ctl_notes,
        mut control_events,
        events_dropped,
    ): (
        Vec<ElasticEvent>,
        Vec<StageTrajectory>,
        Vec<(u64, usize)>,
        Vec<String>,
        Vec<ControlEvent>,
        u64,
    ) = match ctl_thread {
        Some(t) => {
            let outcome = t.join().map_err(|payload| {
                SfError::Scheduler(format!(
                    "elastic controller panicked: {}",
                    panic_message(payload.as_ref())
                ))
            })?;
            (
                outcome.events,
                outcome.trajectories,
                outcome.budget_timeline,
                outcome.notes,
                outcome.control_events,
                outcome.events_dropped,
            )
        }
        None => {
            let dropped = tel_ring.as_ref().map(|r| r.dropped()).unwrap_or(0);
            (Vec::new(), Vec::new(), Vec::new(), Vec::new(), Vec::new(), dropped)
        }
    };
    // Kernel-level faults (panics, the deadline abort) reach the
    // structured journal here: the controller only tails *stage* fault
    // logs live, and with it joined this thread is the ring's sole
    // producer. They are appended to the report's event journal too so
    // the Perfetto export sees them.
    let run_fault_records = run_faults.snapshot();
    for rec in &run_fault_records {
        let ev = ControlEvent::Fault {
            at_ns: rec.at_ns,
            target: rec.target.clone(),
            lane: rec.lane,
            restarts: rec.restarts,
            escalated: rec.escalated,
            message: rec.message.clone(),
        };
        if let Some(ring) = &tel_ring {
            ring.emit(ev.clone());
        }
        control_events.push(ev);
    }
    // Analyzer warnings join the journal the same way — `at_ns: 0`
    // because they predate the kernel phase.
    let analysis_warning_count = analysis.warnings().count() as u64;
    for w in analysis.warnings() {
        let ev = ControlEvent::Note {
            at_ns: 0,
            note: format!("analysis {} ({}): {}", w.rule, w.rule.title(), w.message),
        };
        if let Some(ring) = &tel_ring {
            ring.emit(ev.clone());
        }
        control_events.push(ev);
    }
    if let Some(ring) = &tel_ring {
        if !run_fault_records.is_empty() || analysis_warning_count > 0 {
            ring.sync();
        }
    }
    if let Some(shared) = &tel_shared {
        shared.inc_faults(run_fault_records.len() as u64);
    }
    // Producer (the controller) has stopped: the tail's final drain is
    // complete, and the last scrape window closes after it.
    if let Some(tail) = jsonl_tail {
        tail.shutdown();
    }
    if let Some(srv) = metrics_server {
        srv.shutdown();
    }

    // Placement outcome: read the accumulated pin counters *after* the
    // run so late-spawned replica workers are counted too.
    placement_notes.extend(ctl_notes);
    let placement_report = PlacementReport {
        assignments: stage_pins
            .into_iter()
            .map(|(target, pin, numa_node)| PlacementAssignment {
                target,
                cpus: pin.cpus().to_vec(),
                pinned_threads: pin.applied(),
                denied_threads: pin.denied(),
                numa_node,
                note: pin.note(),
            })
            .collect(),
        notes: placement_notes,
    };

    let mut report = RunReport {
        wall_ns,
        elastic_events,
        replica_trajectories,
        budget_timeline,
        placement: placement_report,
        control_events,
        events_dropped,
        analysis,
        ..Default::default()
    };
    while let Ok(ev) = drain_rx.try_recv() {
        match ev {
            MonitorEvent::Converged { stream, end, estimate } => {
                report.estimates.push((stream, end, estimate));
            }
            MonitorEvent::BestEffort { stream, end, estimate } => {
                report.best_effort.push((stream, end, estimate));
            }
            MonitorEvent::PeriodChanged { stream, period_ns, .. } => {
                report.period_events.push((stream, period_ns));
            }
            MonitorEvent::Failed { stream, reason } => {
                report.failures.push((stream, reason));
            }
            MonitorEvent::Classified { stream, end, class, .. } => {
                report.classifications.push((stream, end, class));
            }
            raw @ MonitorEvent::RawSample { .. } => report.raw_samples.push(raw),
        }
    }
    for edge in topo.streams() {
        let c = edge.monitor.counters();
        report
            .stream_totals
            .insert(edge.label.clone(), (c.total_pushes(), c.total_pops()));
        // Blocked-duration fractions of the kernel-phase wall clock:
        // which streams lost time to backpressure vs starvation. The
        // accumulators are monotonic, so this is a free end-of-run read.
        let wall = wall_ns.max(1) as f64;
        report.stream_blocked.push(StreamBlocked {
            label: edge.label.clone(),
            read_frac: (c.total_read_blocked_ns() as f64 / wall).min(1.0),
            write_frac: (c.total_write_blocked_ns() as f64 / wall).min(1.0),
        });
    }
    // ---- fault & degradation accounting ------------------------------
    // One merged, time-ordered fault history (kernel panics + deadline
    // from the run-level sink, lane panics from each stage's log), and
    // the two audited loss terms of the conservation equation:
    //   delivered + items_lost + items_shed == offered.
    // `items_lost` sums per-item audits (lane losses) with the items
    // stranded in poisoned streams (pushed, never popped — both peers
    // are gone, so these lifetime counters are final).
    let mut faults = run_fault_records;
    let mut items_lost: u64 = 0;
    for decl in &topo.elastic {
        if let Some(log) = decl.stage.fault_log() {
            faults.extend(log.snapshot());
            items_lost += log.items_lost();
        }
    }
    // Network edges: transport faults (dial/handshake/socket failures,
    // corrupt frames, remote poison) recorded by NetSink/NetSource join
    // the merged history, and items a remote peer pushed that never
    // arrived on a poisoned edge (in flight on the wire or in the decode
    // backlog when the transport died) are audited as lost — the
    // cross-process conservation equation stays exact.
    for stats in topo.net_edges.iter() {
        faults.extend(stats.take_faults());
        if stats.is_poisoned() {
            items_lost += stats.in_flight();
        }
    }
    faults.sort_by_key(|r| r.at_ns);
    for edge in topo.streams() {
        if edge.monitor.is_poisoned() {
            let c = edge.monitor.counters();
            items_lost += c.total_pushes().saturating_sub(c.total_pops());
        }
    }
    report.faults = faults;
    report.items_lost = items_lost;
    report.items_shed = shedders.iter().map(|s| s.control.shed_total()).sum();
    report.shed_level = shedders.iter().map(|s| s.control.level()).max().unwrap_or(0);
    report.deadline_hit = deadline_hit;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::{Flow, RunOptions, Session};
    use crate::kernel::{ClosureSink, ClosureSource};
    use crate::queue::StreamConfig;
    use std::sync::{Arc as StdArc, Mutex};

    #[test]
    fn runs_two_kernel_pipeline_to_completion() {
        let n_items = 50_000u64;
        let mut i = 0u64;
        let seen = StdArc::new(Mutex::new(0u64));
        let seen2 = seen.clone();
        let flow = Flow::new("t")
            .stream_defaults(StreamConfig::default().with_capacity(128))
            .source::<u64>(Box::new(ClosureSource::new("src", move || {
                i += 1;
                (i <= n_items).then_some(i)
            })))
            .sink(Box::new(ClosureSink::new("snk", move |_: u64| {
                *seen2.lock().unwrap() += 1;
            })))
            .unwrap();
        let report = Session::run_flow(flow, RunOptions::default()).unwrap();
        assert_eq!(*seen.lock().unwrap(), n_items);
        assert!(report.wall_ns > 0);
        let (pushes, pops) = report.stream_totals["src.0 -> snk.0"];
        assert_eq!(pushes, n_items);
        assert_eq!(pops, n_items);
    }

    #[test]
    fn three_stage_chain_delivers_in_order() {
        struct Doubler;
        impl crate::kernel::Kernel for Doubler {
            fn name(&self) -> &str {
                "double"
            }
            fn run(&mut self, ctx: &mut KernelContext) -> KernelStatus {
                match ctx.input::<u64>(0).unwrap().pop() {
                    Some(v) => {
                        ctx.output::<u64>(0).unwrap().push(v * 2).ok();
                        KernelStatus::Continue
                    }
                    None => KernelStatus::Done,
                }
            }
        }
        let mut i = 0u64;
        let out = StdArc::new(Mutex::new(Vec::new()));
        let out2 = out.clone();
        let flow = Flow::new("chain")
            .source::<u64>(Box::new(ClosureSource::new("src", move || {
                i += 1;
                (i <= 1000).then_some(i)
            })))
            .then::<u64>(Box::new(Doubler))
            .unwrap()
            .sink(Box::new(ClosureSink::new("snk", move |v: u64| {
                out2.lock().unwrap().push(v)
            })))
            .unwrap();
        Session::run_flow(flow, RunOptions::default()).unwrap();
        let v = out.lock().unwrap();
        assert_eq!(v.len(), 1000);
        assert!(v.iter().enumerate().all(|(i, &x)| x == 2 * (i as u64 + 1)));
    }

    #[test]
    fn monitored_run_produces_report_without_hanging() {
        let mut i = 0u64;
        let flow = Flow::new("mon")
            .stream_defaults(StreamConfig::default().with_capacity(256))
            .source::<u64>(Box::new(ClosureSource::new("src", move || {
                i += 1;
                (i <= 200_000).then_some(i)
            })))
            .sink(Box::new(ClosureSink::new("snk", |_: u64| {})))
            .unwrap();
        let report =
            Session::run_flow(flow, RunOptions::monitored(MonitorConfig::practical())).unwrap();
        // The run is too fast for guaranteed convergence; what matters is
        // clean shutdown and total accounting.
        let (pushes, pops) = report.stream_totals["src.0 -> snk.0"];
        assert_eq!(pushes, 200_000);
        assert_eq!(pops, 200_000);
    }

}
