//! Fixed-bin histogram for the report layer (Fig. 13 reproduction).

/// A fixed-range, fixed-width-bin histogram with under/overflow tracking.
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    bins: Vec<u64>,
    underflow: u64,
    overflow: u64,
    count: u64,
}

impl Histogram {
    /// Histogram over [lo, hi) with `nbins` equal-width bins.
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Histogram { lo, hi, bins: vec![0; nbins], underflow: 0, overflow: 0, count: 0 }
    }

    /// Insert one observation.
    pub fn add(&mut self, x: f64) {
        self.count += 1;
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let w = (self.hi - self.lo) / self.bins.len() as f64;
            let idx = ((x - self.lo) / w) as usize;
            let idx = idx.min(self.bins.len() - 1);
            self.bins[idx] += 1;
        }
    }

    /// Total observations (including under/overflow).
    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn underflow(&self) -> u64 {
        self.underflow
    }

    pub fn overflow(&self) -> u64 {
        self.overflow
    }

    /// (bin_center, count) pairs.
    pub fn bins(&self) -> Vec<(f64, u64)> {
        let w = (self.hi - self.lo) / self.bins.len() as f64;
        self.bins
            .iter()
            .enumerate()
            .map(|(i, &c)| (self.lo + w * (i as f64 + 0.5), c))
            .collect()
    }

    /// (bin_center, probability) pairs — Fig. 13's y-axis.
    pub fn probabilities(&self) -> Vec<(f64, f64)> {
        let total = self.count.max(1) as f64;
        self.bins().into_iter().map(|(c, n)| (c, n as f64 / total)).collect()
    }

    /// Fraction of observations with |x| <= bound (in-range mass helper:
    /// "the majority of the results are within 20% of nominal").
    pub fn mass_within(&self, bound: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let w = (self.hi - self.lo) / self.bins.len() as f64;
        let mut inside = 0u64;
        for (i, &c) in self.bins.iter().enumerate() {
            let center = self.lo + w * (i as f64 + 0.5);
            if center.abs() <= bound {
                inside += c;
            }
        }
        inside as f64 / self.count as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bins_and_flows() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for x in [-1.0, 0.0, 0.5, 5.0, 9.99, 10.0, 42.0] {
            h.add(x);
        }
        assert_eq!(h.count(), 7);
        assert_eq!(h.underflow(), 1);
        assert_eq!(h.overflow(), 2);
        let bins = h.bins();
        assert_eq!(bins[0].1, 2); // 0.0 and 0.5
        assert_eq!(bins[5].1, 1); // 5.0
        assert_eq!(bins[9].1, 1); // 9.99
    }

    #[test]
    fn probabilities_sum_to_in_range_mass() {
        let mut h = Histogram::new(-1.0, 1.0, 4);
        for x in [-0.5, 0.0, 0.5, 2.0] {
            h.add(x);
        }
        let total: f64 = h.probabilities().iter().map(|(_, p)| p).sum();
        assert!((total - 0.75).abs() < 1e-12);
    }

    #[test]
    fn mass_within_bound() {
        let mut h = Histogram::new(-100.0, 100.0, 200);
        for i in -50..=50 {
            h.add(i as f64);
        }
        let m = h.mass_within(20.0);
        assert!(m > 0.35 && m < 0.45, "m = {m}");
    }
}
