//! Streaming statistics.
//!
//! Algorithm 1 "presumes there is an implementation of a streaming mean and
//! standard deviation (see Welford [22] and Chan et al. [6])" — that is
//! [`Welford`]. The §VII future-work extension (method-of-moments
//! distribution selection) needs streamed higher moments — that is
//! [`pebay::Moments`] (Pébay [19]). [`quantile`] and [`histogram`] back the
//! report/bench layers.

pub mod histogram;
pub mod pebay;
pub mod quantile;
pub mod welford;

pub use histogram::Histogram;
pub use pebay::Moments;
pub use quantile::{normal_quantile, percentile};
pub use welford::Welford;
