//! Pébay one-pass arbitrary-order moments [19].
//!
//! §VII: "Efficient methods also exist for streaming computation of higher
//! moments" — skewness and kurtosis feed the method-of-moments distribution
//! classifier (`classify`), enabling online selection of a closed-form
//! queueing model. Update formulas from SAND2008-6212 (single-observation
//! case), which generalize Welford to M3/M4.

/// Streaming central moments up to order 4.
#[derive(Debug, Clone, Copy, Default)]
pub struct Moments {
    n: u64,
    mean: f64,
    m2: f64,
    m3: f64,
    m4: f64,
}

impl Moments {
    pub fn new() -> Self {
        Moments::default()
    }

    /// Absorb one observation.
    #[inline]
    pub fn update(&mut self, x: f64) {
        let n1 = self.n as f64;
        self.n += 1;
        let n = self.n as f64;
        let delta = x - self.mean;
        let delta_n = delta / n;
        let delta_n2 = delta_n * delta_n;
        let term1 = delta * delta_n * n1;
        self.mean += delta_n;
        self.m4 += term1 * delta_n2 * (n * n - 3.0 * n + 3.0)
            + 6.0 * delta_n2 * self.m2
            - 4.0 * delta_n * self.m3;
        self.m3 += term1 * delta_n * (n - 2.0) - 3.0 * delta_n * self.m2;
        self.m2 += term1;
    }

    pub fn reset(&mut self) {
        *self = Moments::default();
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (ddof = 1).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Coefficient of variation σ/μ — the classifier's first discriminator
    /// (0 ⇒ deterministic, 1 ⇒ exponential).
    pub fn cv(&self) -> f64 {
        if self.mean == 0.0 {
            0.0
        } else {
            self.std_dev() / self.mean
        }
    }

    /// Sample skewness g1 = √n·M3 / M2^{3/2}.
    pub fn skewness(&self) -> f64 {
        if self.n < 3 || self.m2 == 0.0 {
            return 0.0;
        }
        let n = self.n as f64;
        (n.sqrt() * self.m3) / self.m2.powf(1.5)
    }

    /// Excess kurtosis g2 = n·M4 / M2² − 3.
    pub fn kurtosis_excess(&self) -> f64 {
        if self.n < 4 || self.m2 == 0.0 {
            return 0.0;
        }
        let n = self.n as f64;
        (n * self.m4) / (self.m2 * self.m2) - 3.0
    }

    /// Pairwise merge (SAND2008-6212 eqs. 1.5–2.x), exact.
    pub fn merge(&self, o: &Moments) -> Moments {
        if self.n == 0 {
            return *o;
        }
        if o.n == 0 {
            return *self;
        }
        let (na, nb) = (self.n as f64, o.n as f64);
        let n = na + nb;
        let delta = o.mean - self.mean;
        let delta2 = delta * delta;
        let delta3 = delta2 * delta;
        let delta4 = delta2 * delta2;
        let mean = self.mean + delta * nb / n;
        let m2 = self.m2 + o.m2 + delta2 * na * nb / n;
        let m3 = self.m3
            + o.m3
            + delta3 * na * nb * (na - nb) / (n * n)
            + 3.0 * delta * (na * o.m2 - nb * self.m2) / n;
        let m4 = self.m4
            + o.m4
            + delta4 * na * nb * (na * na - na * nb + nb * nb) / (n * n * n)
            + 6.0 * delta2 * (na * na * o.m2 + nb * nb * self.m2) / (n * n)
            + 4.0 * delta * (na * o.m3 - nb * self.m3) / n;
        Moments { n: self.n + o.n, mean, m2, m3, m4 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    fn naive(xs: &[f64]) -> (f64, f64, f64, f64) {
        let n = xs.len() as f64;
        let mean = xs.iter().sum::<f64>() / n;
        let m2 = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>();
        let m3 = xs.iter().map(|x| (x - mean).powi(3)).sum::<f64>();
        let m4 = xs.iter().map(|x| (x - mean).powi(4)).sum::<f64>();
        (mean, m2, m3, m4)
    }

    #[test]
    fn matches_naive_moments() {
        let mut rng = Xoshiro256pp::new(4);
        let xs: Vec<f64> = (0..4000).map(|_| rng.exponential(2.0)).collect();
        let mut m = Moments::new();
        xs.iter().for_each(|&x| m.update(x));
        let (mean, m2, m3, m4) = naive(&xs);
        assert!((m.mean - mean).abs() < 1e-9);
        assert!((m.m2 - m2).abs() / m2 < 1e-9);
        assert!((m.m3 - m3).abs() / m3.abs() < 1e-7);
        assert!((m.m4 - m4).abs() / m4 < 1e-7);
    }

    #[test]
    fn exponential_signature() {
        // Exponential: cv = 1, skew = 2, excess kurtosis = 6.
        let mut rng = Xoshiro256pp::new(5);
        let mut m = Moments::new();
        for _ in 0..400_000 {
            m.update(rng.exponential(3.0));
        }
        assert!((m.cv() - 1.0).abs() < 0.02, "cv = {}", m.cv());
        assert!((m.skewness() - 2.0).abs() < 0.15, "skew = {}", m.skewness());
        assert!((m.kurtosis_excess() - 6.0).abs() < 1.0, "kurt = {}", m.kurtosis_excess());
    }

    #[test]
    fn uniform_signature() {
        // Uniform: skew = 0, excess kurtosis = -1.2.
        let mut rng = Xoshiro256pp::new(6);
        let mut m = Moments::new();
        for _ in 0..200_000 {
            m.update(rng.uniform(0.0, 1.0));
        }
        assert!(m.skewness().abs() < 0.03);
        assert!((m.kurtosis_excess() + 1.2).abs() < 0.05);
    }

    #[test]
    fn merge_equals_sequential() {
        let mut rng = Xoshiro256pp::new(7);
        let xs: Vec<f64> = (0..3000).map(|_| rng.exponential(1.0)).collect();
        let mut all = Moments::new();
        xs.iter().for_each(|&x| all.update(x));
        let (mut a, mut b) = (Moments::new(), Moments::new());
        xs[..1111].iter().for_each(|&x| a.update(x));
        xs[1111..].iter().for_each(|&x| b.update(x));
        let m = a.merge(&b);
        assert!((m.mean() - all.mean()).abs() < 1e-9);
        assert!((m.skewness() - all.skewness()).abs() < 1e-7);
        assert!((m.kurtosis_excess() - all.kurtosis_excess()).abs() < 1e-6);
    }

    #[test]
    fn constant_stream() {
        let mut m = Moments::new();
        for _ in 0..100 {
            m.update(7.5);
        }
        assert_eq!(m.mean(), 7.5);
        assert_eq!(m.variance(), 0.0);
        assert_eq!(m.skewness(), 0.0);
        assert_eq!(m.kurtosis_excess(), 0.0);
    }
}
