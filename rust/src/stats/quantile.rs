//! Quantile helpers: the Eq.-3 normal quantile and empirical percentiles.

/// The paper's Eq. 3 z-score for the 95th percentile, locked to the text.
pub const Z_95: f64 = 1.64485;

/// Parametric normal quantile: `μ + z_p·σ` with z from Acklam's inverse-CDF
/// approximation (|rel err| < 1.15e-9). `NQuantileFunction(μ, σ, p)` in
/// Algorithm 1 (the heuristic itself always calls it with p = 0.95 and the
/// hard-coded 1.64485; this general form backs tests and the classifier).
pub fn normal_quantile(mu: f64, sigma: f64, p: f64) -> f64 {
    assert!((0.0..1.0).contains(&p) && p > 0.0, "p in (0,1) required: {p}");
    mu + sigma * standard_normal_inv_cdf(p)
}

/// Acklam's rational approximation to Φ⁻¹.
pub fn standard_normal_inv_cdf(p: f64) -> f64 {
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    const P_LOW: f64 = 0.02425;
    let q;
    if p < P_LOW {
        let r = (-2.0 * p.ln()).sqrt();
        q = (((((C[0] * r + C[1]) * r + C[2]) * r + C[3]) * r + C[4]) * r + C[5])
            / ((((D[0] * r + D[1]) * r + D[2]) * r + D[3]) * r + 1.0);
    } else if p <= 1.0 - P_LOW {
        let r = p - 0.5;
        let s = r * r;
        q = (((((A[0] * s + A[1]) * s + A[2]) * s + A[3]) * s + A[4]) * s + A[5]) * r
            / (((((B[0] * s + B[1]) * s + B[2]) * s + B[3]) * s + B[4]) * s + 1.0);
    } else {
        let r = (-2.0 * (1.0 - p).ln()).sqrt();
        q = -(((((C[0] * r + C[1]) * r + C[2]) * r + C[3]) * r + C[4]) * r + C[5])
            / ((((D[0] * r + D[1]) * r + D[2]) * r + D[3]) * r + 1.0);
    }
    q
}

/// Empirical percentile (linear interpolation, the "R-7" definition).
/// `p` in [0, 100]. Sorts a copy — use for reporting, not hot paths.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty slice");
    assert!((0.0..=100.0).contains(&p));
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, p)
}

/// Empirical percentile over an already-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    let n = sorted.len();
    if n == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (n - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inv_cdf_key_points() {
        assert!((standard_normal_inv_cdf(0.5)).abs() < 1e-9);
        assert!((standard_normal_inv_cdf(0.95) - 1.6448536269514722).abs() < 1e-6);
        assert!((standard_normal_inv_cdf(0.975) - 1.959963984540054).abs() < 1e-6);
        assert!((standard_normal_inv_cdf(0.05) + 1.6448536269514722).abs() < 1e-6);
    }

    #[test]
    fn paper_z_is_the_95th() {
        // The hard-coded 1.64485 is the 95th-percentile z (to 5 decimals).
        assert!((Z_95 - standard_normal_inv_cdf(0.95)).abs() < 1e-4);
    }

    #[test]
    fn normal_quantile_affine() {
        let q = normal_quantile(10.0, 2.0, 0.95);
        assert!((q - (10.0 + 2.0 * 1.6448536269514722)).abs() < 1e-5);
    }

    #[test]
    fn percentile_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 25.0), 2.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert_eq!(percentile(&xs, 50.0), 5.0);
        assert_eq!(percentile(&xs, 95.0), 9.5);
    }

    #[test]
    fn percentile_unsorted_input() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 50.0), 3.0);
    }

    #[test]
    #[should_panic]
    fn percentile_empty_panics() {
        percentile(&[], 50.0);
    }
}
