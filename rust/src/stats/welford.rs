//! Welford's streaming mean/variance [22] with Chan et al. merging [6].
//!
//! This is the `updateStats()` / `getMeanQ()` / `resetStats()` machinery of
//! Algorithm 1: the heuristic streams successive quantile estimates `q`
//! through one of these and reads back the running mean `q̄` and the
//! standard *error* of that mean (whose trace drives convergence, §IV-B).

/// Numerically stable streaming mean and variance.
#[derive(Debug, Clone, Copy, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    /// Empty accumulator.
    pub fn new() -> Self {
        Welford::default()
    }

    /// `updateStats(x)`.
    #[inline]
    pub fn update(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        let delta2 = x - self.mean;
        self.m2 += delta * delta2;
    }

    /// `resetStats()`.
    pub fn reset(&mut self) {
        *self = Welford::default();
    }

    /// Number of samples absorbed.
    #[inline]
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Running mean (`getMeanQ()` when fed `q` values). 0 when empty.
    #[inline]
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample (ddof = 1) variance; 0 for n < 2.
    #[inline]
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    /// Sample standard deviation.
    #[inline]
    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Standard error of the mean — the "σ of q̄" whose decay Algorithm 1
    /// watches for convergence.
    #[inline]
    pub fn std_error(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.std_dev() / (self.n as f64).sqrt()
        }
    }

    /// Chan et al. [6] pairwise merge: combine two accumulators exactly.
    pub fn merge(&self, other: &Welford) -> Welford {
        if self.n == 0 {
            return *other;
        }
        if other.n == 0 {
            return *self;
        }
        let n = self.n + other.n;
        let delta = other.mean - self.mean;
        let mean = self.mean + delta * other.n as f64 / n as f64;
        let m2 = self.m2
            + other.m2
            + delta * delta * (self.n as f64 * other.n as f64) / n as f64;
        Welford { n, mean, m2 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    fn naive(xs: &[f64]) -> (f64, f64) {
        let n = xs.len() as f64;
        let mean = xs.iter().sum::<f64>() / n;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1.0);
        (mean, var)
    }

    #[test]
    fn matches_naive_two_pass() {
        let mut rng = Xoshiro256pp::new(1);
        let xs: Vec<f64> = (0..5000).map(|_| rng.uniform(-100.0, 100.0)).collect();
        let mut w = Welford::new();
        xs.iter().for_each(|&x| w.update(x));
        let (mean, var) = naive(&xs);
        assert!((w.mean() - mean).abs() < 1e-9);
        assert!((w.variance() - var).abs() / var < 1e-9);
    }

    #[test]
    fn catastrophic_cancellation_case() {
        // Large offset, small spread — the case the textbook formula loses.
        let base = 1.0e9;
        let xs: Vec<f64> = (0..1000).map(|i| base + (i % 7) as f64).collect();
        let mut w = Welford::new();
        xs.iter().for_each(|&x| w.update(x));
        let (_, var) = naive(&xs);
        assert!((w.variance() - var).abs() / var < 1e-6, "{} vs {}", w.variance(), var);
    }

    #[test]
    fn merge_equals_sequential() {
        let mut rng = Xoshiro256pp::new(2);
        let xs: Vec<f64> = (0..2000).map(|_| rng.exponential(5.0)).collect();
        let mut all = Welford::new();
        xs.iter().for_each(|&x| all.update(x));
        let (mut a, mut b) = (Welford::new(), Welford::new());
        xs[..700].iter().for_each(|&x| a.update(x));
        xs[700..].iter().for_each(|&x| b.update(x));
        let m = a.merge(&b);
        assert_eq!(m.count(), all.count());
        assert!((m.mean() - all.mean()).abs() < 1e-9);
        assert!((m.variance() - all.variance()).abs() / all.variance() < 1e-9);
    }

    #[test]
    fn std_error_decays() {
        let mut rng = Xoshiro256pp::new(3);
        let mut w = Welford::new();
        let mut prev = f64::INFINITY;
        for block in 0..5 {
            for _ in 0..2000 {
                w.update(rng.uniform(0.0, 1.0));
            }
            let se = w.std_error();
            assert!(se < prev, "block {block}: {se} !< {prev}");
            prev = se;
        }
    }

    #[test]
    fn reset_clears() {
        let mut w = Welford::new();
        w.update(1.0);
        w.update(2.0);
        w.reset();
        assert_eq!(w.count(), 0);
        assert_eq!(w.mean(), 0.0);
        assert_eq!(w.variance(), 0.0);
    }

    #[test]
    fn empty_and_single() {
        let w = Welford::new();
        assert_eq!(w.std_error(), 0.0);
        let mut w1 = Welford::new();
        w1.update(5.0);
        assert_eq!(w1.mean(), 5.0);
        assert_eq!(w1.variance(), 0.0);
    }
}
