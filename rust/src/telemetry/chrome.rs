//! Perfetto / `chrome://tracing` timeline export.
//!
//! [`crate::scheduler::RunReport::write_chrome_trace`] serializes the
//! run's control-plane history into the Trace Event JSON format: open the
//! file at <https://ui.perfetto.dev> (or `chrome://tracing`) to see
//!
//! * one **counter track per elastic stage** (replica count over time)
//!   plus the coordinated worker-budget counter,
//! * one **track per replica lane** with its lifetime as a duration span
//!   (spawns and retirements are visible as the span edges),
//! * one **track per stream** carrying read/write **blocked spans**, and
//! * **instant events** on the control-plane track for every scale,
//!   resize, gate, budget change, note, converged rate estimate, and —
//!   from the supervision layer — every **fault** (lane/kernel panic,
//!   deadline abort) and **stall suspicion**, plus a **degradation-level
//!   counter track** per shedding source.
//!
//! Timestamps are re-based so the earliest control-plane event is t=0;
//! microsecond floats as the format requires.

use std::collections::BTreeMap;
use std::path::Path;

use crate::config::Json;
use crate::elastic::ElasticAction;
use crate::error::Result;
use crate::scheduler::RunReport;

use super::ring::ControlEvent;

const PID: f64 = 1.0;
const TID_CONTROL: f64 = 1.0;
const TID_STREAM_BASE: u64 = 200;
const TID_LANE_BASE: u64 = 1000;
/// Lane tids are `TID_LANE_BASE + stage_index * TID_LANE_STRIDE + lane`.
const TID_LANE_STRIDE: u64 = 64;

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn event(name: &str, ph: &str, ts_us: f64, tid: f64, extra: Vec<(&str, Json)>) -> Json {
    let mut pairs = vec![
        ("name", Json::Str(name.to_string())),
        ("ph", Json::Str(ph.to_string())),
        ("ts", Json::Num(ts_us)),
        ("pid", Json::Num(PID)),
        ("tid", Json::Num(tid)),
    ];
    pairs.extend(extra);
    obj(pairs)
}

fn thread_name(tid: f64, name: &str) -> Json {
    event(
        "thread_name",
        "M",
        0.0,
        tid,
        vec![("args", obj(vec![("name", Json::Str(name.to_string()))]))],
    )
}

/// Build the full trace object for a report.
pub fn trace_json(report: &RunReport) -> Json {
    // Re-base: all at_ns values share the run's TimeRef clock; wall_ns is
    // a duration. Find the earliest and latest control-plane timestamps.
    let mut t_min = u64::MAX;
    let mut t_max = 0u64;
    let mut see = |t: u64| {
        t_min = t_min.min(t);
        t_max = t_max.max(t);
    };
    for tr in &report.replica_trajectories {
        for &(t, _) in &tr.points {
            see(t);
        }
    }
    for &(t, _) in &report.budget_timeline {
        see(t);
    }
    for e in &report.elastic_events {
        see(e.at_ns);
    }
    for ev in &report.control_events {
        see(ev.at_ns());
    }
    let t0 = if t_min == u64::MAX { 0 } else { t_min };
    let t_end = t_max.max(t0.saturating_add(report.wall_ns));
    let us = |t: u64| (t.saturating_sub(t0)) as f64 / 1000.0;

    let mut events: Vec<Json> = Vec::new();
    events.push(event(
        "process_name",
        "M",
        0.0,
        TID_CONTROL,
        vec![("args", obj(vec![("name", Json::Str("streamflow".into()))]))],
    ));
    events.push(thread_name(TID_CONTROL, "control plane"));

    // --- stage replica counters + lane lifetime tracks -----------------
    for (si, tr) in report.replica_trajectories.iter().enumerate() {
        for &(t, r) in &tr.points {
            events.push(event(
                &format!("{} replicas", tr.stage),
                "C",
                us(t),
                TID_CONTROL,
                vec![("args", obj(vec![("replicas", Json::Num(r as f64))]))],
            ));
        }
        if let Some(&(_, r)) = tr.points.last() {
            events.push(event(
                &format!("{} replicas", tr.stage),
                "C",
                us(t_end),
                TID_CONTROL,
                vec![("args", obj(vec![("replicas", Json::Num(r as f64))]))],
            ));
        }

        // Lane lifetimes: baseline lanes open at the trajectory origin;
        // spawn/retire events from the ring open and close the rest.
        let mut open: BTreeMap<usize, u64> = BTreeMap::new();
        let mut lanes_seen: std::collections::BTreeSet<usize> = std::collections::BTreeSet::new();
        if let Some(&(t_base, r0)) = tr.points.first() {
            for lane in 0..r0 {
                open.insert(lane, t_base);
                lanes_seen.insert(lane);
            }
        }
        let lane_tid = |lane: usize| {
            (TID_LANE_BASE + si as u64 * TID_LANE_STRIDE + (lane as u64 % TID_LANE_STRIDE))
                as f64
        };
        let mut close_lane = |events: &mut Vec<Json>, lane: usize, from: u64, to: u64| {
            events.push(event(
                "lane",
                "X",
                us(from),
                lane_tid(lane),
                vec![
                    ("dur", Json::Num((to.saturating_sub(from)) as f64 / 1000.0)),
                    ("args", obj(vec![("lane", Json::Num(lane as f64))])),
                ],
            ));
        };
        for ev in &report.control_events {
            if let ControlEvent::Lane { at_ns, stage, lane, spawned } = ev {
                if stage != &tr.stage {
                    continue;
                }
                lanes_seen.insert(*lane);
                if *spawned {
                    open.entry(*lane).or_insert(*at_ns);
                } else if let Some(from) = open.remove(lane) {
                    close_lane(&mut events, *lane, from, *at_ns);
                }
            }
        }
        let leftover: Vec<(usize, u64)> = open.into_iter().collect();
        for (lane, from) in leftover {
            close_lane(&mut events, lane, from, t_end);
        }
        for lane in lanes_seen {
            events.push(thread_name(lane_tid(lane), &format!("{}/lane{}", tr.stage, lane)));
        }
    }

    // --- worker budget counter -----------------------------------------
    for &(t, b) in &report.budget_timeline {
        events.push(event(
            "worker budget",
            "C",
            us(t),
            TID_CONTROL,
            vec![("args", obj(vec![("budget", Json::Num(b as f64))]))],
        ));
    }
    if let Some(&(_, b)) = report.budget_timeline.last() {
        events.push(event(
            "worker budget",
            "C",
            us(t_end),
            TID_CONTROL,
            vec![("args", obj(vec![("budget", Json::Num(b as f64))]))],
        ));
    }

    // --- scale / resize instants ---------------------------------------
    for e in &report.elastic_events {
        let name = match e.action {
            ElasticAction::ScaleUp { from, to } => {
                format!("{} scale-up {from}->{to}", e.target)
            }
            ElasticAction::ScaleDown { from, to } => {
                format!("{} scale-down {from}->{to}", e.target)
            }
            ElasticAction::Resize { from, to, .. } => {
                format!("{} resize {from}->{to}", e.target)
            }
        };
        events.push(event(
            &name,
            "i",
            us(e.at_ns),
            TID_CONTROL,
            vec![
                ("s", Json::Str("t".into())),
                (
                    "args",
                    obj(vec![
                        ("rho", Json::Num(e.rho)),
                        ("lambda_items", Json::Num(e.lambda_items)),
                        ("mu_items", Json::Num(e.mu_items)),
                        ("pressure", Json::Bool(e.pressure)),
                    ]),
                ),
            ],
        ));
    }

    // --- stream tracks: blocked spans + structured instants ------------
    let mut stream_tids: BTreeMap<String, u64> = BTreeMap::new();
    {
        let mut tid_for = |label: &str, events: &mut Vec<Json>| -> f64 {
            if let Some(t) = stream_tids.get(label) {
                return *t as f64;
            }
            let tid = TID_STREAM_BASE + stream_tids.len() as u64;
            stream_tids.insert(label.to_string(), tid);
            events.push(thread_name(tid as f64, label));
            tid as f64
        };
        for ev in &report.control_events {
            match ev {
                ControlEvent::BlockedSpan { at_ns, label, end, dur_ns } => {
                    let tid = tid_for(label, &mut events);
                    let start = at_ns.saturating_sub(*dur_ns);
                    events.push(event(
                        match end {
                            super::ring::BlockEnd::Read => "read-blocked",
                            super::ring::BlockEnd::Write => "write-blocked",
                        },
                        "X",
                        us(start),
                        tid,
                        vec![("dur", Json::Num(*dur_ns as f64 / 1000.0))],
                    ));
                }
                ControlEvent::ScaleGated { at_ns, stage, replicas, wanted, reason } => {
                    events.push(event(
                        &format!("{stage} gated ({})", reason.as_str()),
                        "i",
                        us(*at_ns),
                        TID_CONTROL,
                        vec![
                            ("s", Json::Str("t".into())),
                            (
                                "args",
                                obj(vec![
                                    ("replicas", Json::Num(*replicas as f64)),
                                    ("wanted", Json::Num(*wanted as f64)),
                                ]),
                            ),
                        ],
                    ));
                }
                ControlEvent::RateConverged { at_ns, stream, end, mbps } => {
                    events.push(event(
                        "rate converged",
                        "i",
                        us(*at_ns),
                        TID_CONTROL,
                        vec![
                            ("s", Json::Str("t".into())),
                            (
                                "args",
                                obj(vec![
                                    ("stream", Json::Num(stream.0 as f64)),
                                    (
                                        "end",
                                        Json::Str(
                                            match end {
                                                crate::monitor::QueueEnd::Head => "head",
                                                crate::monitor::QueueEnd::Tail => "tail",
                                            }
                                            .into(),
                                        ),
                                    ),
                                    ("mbps", Json::Num(*mbps)),
                                ]),
                            ),
                        ],
                    ));
                }
                ControlEvent::Note { at_ns, note } => {
                    events.push(event(
                        "note",
                        "i",
                        us(*at_ns),
                        TID_CONTROL,
                        vec![
                            ("s", Json::Str("t".into())),
                            ("args", obj(vec![("note", Json::Str(note.clone()))])),
                        ],
                    ));
                }
                ControlEvent::Fault { at_ns, target, lane, restarts, escalated, message } => {
                    let mut args = vec![
                        ("restarts", Json::Num(*restarts as f64)),
                        ("escalated", Json::Bool(*escalated)),
                        ("message", Json::Str(message.clone())),
                    ];
                    if let Some(lane) = lane {
                        args.push(("lane", Json::Num(*lane as f64)));
                    }
                    events.push(event(
                        &format!("{target} fault"),
                        "i",
                        us(*at_ns),
                        TID_CONTROL,
                        vec![("s", Json::Str("t".into())), ("args", obj(args))],
                    ));
                }
                ControlEvent::StallSuspected { at_ns, stage, epochs } => {
                    events.push(event(
                        &format!("{stage} stall suspected"),
                        "i",
                        us(*at_ns),
                        TID_CONTROL,
                        vec![
                            ("s", Json::Str("t".into())),
                            ("args", obj(vec![("epochs", Json::Num(*epochs as f64))])),
                        ],
                    ));
                }
                ControlEvent::Shed { at_ns, target, level, shed_total } => {
                    events.push(event(
                        &format!("degradation {target}"),
                        "C",
                        us(*at_ns),
                        TID_CONTROL,
                        vec![(
                            "args",
                            obj(vec![
                                ("level", Json::Num(*level as f64)),
                                ("shed_total", Json::Num(*shed_total as f64)),
                            ]),
                        )],
                    ));
                }
                _ => {}
            }
        }
    }

    // --- whole-run blocked fractions (non-elastic runs still get data) --
    for sb in &report.stream_blocked {
        if sb.read_frac <= 0.0 && sb.write_frac <= 0.0 {
            continue;
        }
        events.push(event(
            &format!("blocked% {}", sb.label),
            "C",
            0.0,
            TID_CONTROL,
            vec![(
                "args",
                obj(vec![
                    ("read_pct", Json::Num(sb.read_frac * 100.0)),
                    ("write_pct", Json::Num(sb.write_frac * 100.0)),
                ]),
            )],
        ));
    }

    obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::Str("ms".into())),
    ])
}

/// Serialize [`trace_json`] to `path`.
pub fn write_trace(report: &RunReport, path: &Path) -> Result<()> {
    std::fs::write(path, trace_json(report).to_string())?;
    Ok(())
}
