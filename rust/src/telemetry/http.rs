//! Std-only blocking HTTP exporter for the Prometheus scrape.
//!
//! The `sf-metrics` thread serves `GET /metrics` (and `GET /`) with the
//! registry's current render — one connection at a time, HTTP/1.1 with
//! `Connection: close`. That is exactly enough for a scraper at human
//! cadence and keeps the exporter dependency-free.
//!
//! Since the distributed data plane landed, the accept machinery is the
//! shared [`crate::net::AcceptLoop`] (the same loop that fronts
//! [`crate::net::NetListener`]); this module is just the per-connection
//! HTTP handler plus a stable [`MetricsServer`] handle, so its behavior
//! and endpoint are unchanged from the hand-rolled original.
//!
//! Off by default: the thread only exists when
//! [`crate::telemetry::TelemetryConfig::metrics_addr`] is set (CLI:
//! `--metrics-addr 127.0.0.1:9898`; port 0 binds an ephemeral port, the
//! realized address is readable via [`MetricsServer::local_addr`]).

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use crate::error::Result;
use crate::net::AcceptLoop;

use super::registry::MetricsRegistry;

/// Handle to the scrape endpoint; wraps the shared accept loop.
#[derive(Debug)]
pub struct MetricsServer {
    inner: AcceptLoop,
}

impl MetricsServer {
    /// Bind `addr` and start serving `registry.render()` until
    /// [`MetricsServer::shutdown`] (or drop).
    pub fn spawn(addr: &str, registry: Arc<MetricsRegistry>) -> Result<MetricsServer> {
        let inner =
            AcceptLoop::spawn(addr, "sf-metrics", move |conn| serve_one(conn, &registry))?;
        Ok(MetricsServer { inner })
    }

    /// The realized bind address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.inner.local_addr()
    }

    /// Stop accepting and join the serving thread.
    pub fn shutdown(self) {
        self.inner.shutdown();
    }
}

/// Handle one scrape connection; errors just drop the connection.
fn serve_one(mut conn: TcpStream, registry: &MetricsRegistry) {
    let _ = conn.set_nonblocking(false);
    let _ = conn.set_read_timeout(Some(Duration::from_millis(500)));
    let _ = conn.set_write_timeout(Some(Duration::from_millis(500)));

    // Read the request head (we only need the request line).
    let mut buf = Vec::with_capacity(512);
    let mut chunk = [0u8; 512];
    loop {
        match conn.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => {
                buf.extend_from_slice(&chunk[..n]);
                if buf.windows(4).any(|w| w == b"\r\n\r\n") || buf.len() > 8192 {
                    break;
                }
            }
            Err(_) => break,
        }
    }
    let head = String::from_utf8_lossy(&buf);
    let mut parts = head.lines().next().unwrap_or("").split_whitespace();
    let method = parts.next().unwrap_or("");
    let path = parts.next().unwrap_or("");

    let (status, body) = if method == "GET" && (path == "/metrics" || path == "/") {
        ("200 OK", registry.render())
    } else {
        ("404 Not Found", "not found; scrape /metrics\n".to_string())
    };
    let _ = write!(
        conn,
        "HTTP/1.1 {status}\r\nContent-Type: text/plain; version=0.0.4\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    let _ = conn.flush();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::queue::{instrumented, StreamConfig};
    use crate::topology::StreamId;

    fn http_get(addr: SocketAddr, path: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        write!(s, "GET {path} HTTP/1.1\r\nHost: localhost\r\nConnection: close\r\n\r\n").unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn serves_metrics_and_404s_everything_else() {
        let (q, h) = instrumented::<u64>(&StreamConfig::default());
        q.try_push(7).unwrap();
        let mut reg = MetricsRegistry::standalone();
        reg.add_stream(StreamId(0), "src.0 -> snk.0", h);
        let srv = MetricsServer::spawn("127.0.0.1:0", Arc::new(reg)).unwrap();
        let addr = srv.local_addr();

        let resp = http_get(addr, "/metrics");
        assert!(resp.starts_with("HTTP/1.1 200 OK"), "{resp}");
        assert!(resp.contains("text/plain; version=0.0.4"), "{resp}");
        assert!(resp.contains("sf_stream_pushes_total{stream=\"src.0 -> snk.0\"} 1"), "{resp}");

        let missing = http_get(addr, "/nope");
        assert!(missing.starts_with("HTTP/1.1 404"), "{missing}");

        // A second scrape still works (one-connection-at-a-time loop).
        let resp2 = http_get(addr, "/");
        assert!(resp2.starts_with("HTTP/1.1 200 OK"), "{resp2}");
        srv.shutdown();
    }
}
