//! JSONL event tail: every control-plane event as one JSON object per
//! line, written live while the run executes.
//!
//! Enable with [`crate::telemetry::TelemetryConfig::with_jsonl`] (CLI:
//! `--events-jsonl events.jsonl`). A dedicated thread (`sf-telemetry`)
//! tails the [`super::ring::EventRing`] journal incrementally (~20 ms
//! cadence) and performs a final drain at shutdown, so the file is
//! complete even for events emitted in the run's last tick.
//!
//! # Line schema
//!
//! Every line is a JSON object with `"type"` and `"at_ns"` (u64
//! nanoseconds on the run's monotonic clock; time zero is process-local).
//! Per-type fields:
//!
//! | `type` | fields |
//! |---|---|
//! | `action` | `target`, `action` (`scale-up`\|`scale-down`\|`resize`), `from`, `to`, `rho`, `lambda_items`, `mu_items`, `pressure`, `starved_frac`, `backpressure_frac`; `model` on resizes |
//! | `budget` | `budget` (coordinated replica budget now in force) |
//! | `note` | `note` (free-form control-plane annotation) |
//! | `scale-gated` | `stage`, `replicas`, `wanted`, `reason` (`starved`\|`downstream-blocked`\|`budget`) |
//! | `lane` | `stage`, `lane` (index), `event` (`spawn`\|`retire`) |
//! | `blocked-span` | `stream` (label), `end` (`read`\|`write`), `dur_ns`; `at_ns` is the span **end** |
//! | `rate-converged` | `stream` (numeric id), `end` (`head`\|`tail`), `mbps` |
//! | `fault` | `target` (stage/kernel/`session`), `restarts`, `escalated` (bool), `message` (panic payload or abort reason); `lane` (index) on lane panics |
//! | `stall-suspected` | `stage`, `epochs` (consecutive zero-progress control epochs) |
//! | `shed` | `target` (source label), `level` (degradation level now in force), `shed_total` (lifetime items shed at this source) |
//!
//! The schema is additive: consumers must ignore unknown fields and
//! unknown `type`s.

use std::io::Write as _;
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::error::Result;

use super::ring::EventRing;

/// Handle to the JSONL tail thread.
pub struct JsonlTail {
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl std::fmt::Debug for JsonlTail {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("JsonlTail").finish()
    }
}

impl JsonlTail {
    /// Create (truncate) `path` and start tailing `ring` into it.
    pub fn spawn(path: &Path, ring: Arc<EventRing>) -> Result<JsonlTail> {
        let file = std::fs::File::create(path)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let thread = std::thread::Builder::new()
            .name("sf-telemetry".into())
            .spawn(move || {
                let mut out = std::io::BufWriter::new(file);
                let mut cursor = 0usize;
                loop {
                    let done = stop2.load(Ordering::Acquire);
                    let (events, next) = ring.read_from(cursor);
                    cursor = next;
                    for ev in &events {
                        let line = ev.to_json().to_string();
                        let _ = writeln!(out, "{line}");
                    }
                    if !events.is_empty() {
                        let _ = out.flush();
                    }
                    if done {
                        break;
                    }
                    std::thread::sleep(Duration::from_millis(20));
                }
                let _ = out.flush();
            })?;
        Ok(JsonlTail { stop, thread: Some(thread) })
    }

    /// Final drain + flush + join. Call after the producer has stopped so
    /// the last tick's events land in the file.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for JsonlTail {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Json;
    use crate::telemetry::ControlEvent;

    #[test]
    fn tail_writes_every_event_once_in_order() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("sf_jsonl_test_{}.jsonl", std::process::id()));
        let ring = Arc::new(EventRing::new(64));
        for k in 0..5u64 {
            ring.emit(ControlEvent::Note { at_ns: k, note: format!("n{k}") });
        }
        let tail = JsonlTail::spawn(&path, ring.clone()).unwrap();
        // Emit more while the tailer runs, then stop.
        for k in 5..9u64 {
            ring.emit(ControlEvent::Note { at_ns: k, note: format!("n{k}") });
        }
        tail.shutdown();
        let text = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 9, "{text}");
        for (k, line) in lines.iter().enumerate() {
            let j = Json::parse(line).expect("line parses");
            assert_eq!(j.get("type").and_then(Json::as_str), Some("note"));
            assert_eq!(j.get("at_ns").and_then(Json::as_f64), Some(k as f64));
        }
    }
}
