//! Live observability plane: metrics registry, `/metrics` endpoint,
//! structured event ring, JSONL event tail, and Perfetto trace export.
//!
//! The paper's premise is *online* service-rate approximation — yet until
//! this module everything the runtime learned (rates, blocked durations,
//! scaling and budget decisions) was only visible post-mortem in
//! [`crate::scheduler::RunReport`]. The telemetry plane makes the same
//! state observable while the run executes, without adding a single
//! atomic to the data path:
//!
//! * [`registry::MetricsRegistry`] — pull-model Prometheus text built
//!   from the **already-free** counter reads (the SPSC queue's monotonic
//!   head/tail indices are the pop/push counters) plus a small
//!   controller-refreshed gauge block ([`registry::MetricsShared`]);
//!   segmented streams additionally export the `sf_queue_segments`
//!   gauge (segments currently owned, free list included) and the
//!   `sf_segment_allocs_total` counter (heap segment allocations since
//!   construction) — both render `0` for the classic ring backend;
//!   network edges registered through
//!   [`crate::topology::Topology::register_net_edge`] export the
//!   `sf_net_frames_total` / `sf_net_bytes_total` /
//!   `sf_net_reconnects_total` counters and the `sf_net_in_flight` /
//!   `sf_net_poisoned` gauges (one series per `edge` label), so a
//!   sharded coordinator's scrape covers its process boundaries too;
//! * [`ring::EventRing`] — a bounded lock-free ring the controller
//!   publishes structured [`ControlEvent`]s into (scales with gate
//!   reasons, budget recomputes, resizes, lane spawns/retires, blocked
//!   spans, converged rates); it replaces the old ad-hoc `Vec`
//!   accumulation as the single source for
//!   [`crate::elastic::ControlPlaneReport`] timelines, and its overflow
//!   is audited (`events_dropped`), never silent;
//! * exporters — [`http::MetricsServer`] (std-only blocking HTTP
//!   `GET /metrics`), [`jsonl::JsonlTail`] (line-per-event live log, see
//!   [`jsonl`] for the schema), and [`chrome::write_trace`] /
//!   `RunReport::write_chrome_trace` (Perfetto timeline).
//!
//! Everything is **off by default**; [`TelemetryConfig`] on
//! [`crate::flow::RunOptions`] switches the exporters on (CLI:
//! `--metrics-addr`, `--events-jsonl`, `--trace-out`).

pub mod chrome;
pub mod http;
pub mod jsonl;
pub mod registry;
pub mod ring;

pub use http::MetricsServer;
pub use jsonl::JsonlTail;
pub use registry::{MetricsRegistry, MetricsShared};
pub use ring::{BlockEnd, ControlEvent, EventRing, GateReason};

use std::net::SocketAddr;
use std::path::PathBuf;
use std::sync::{Arc, OnceLock};

/// Default bound on undrained control events between two ring drains.
pub const DEFAULT_RING_CAPACITY: usize = 4096;

/// Exporter configuration carried by [`crate::flow::RunOptions`]. All
/// exporters default to off; constructing the config costs nothing.
#[derive(Debug, Clone, Default)]
pub struct TelemetryConfig {
    /// Serve Prometheus text on this address (e.g. `"127.0.0.1:9898"`,
    /// port 0 for ephemeral) for the duration of the run.
    pub metrics_addr: Option<String>,
    /// Tail every control-plane event into this file, one JSON object
    /// per line (schema: [`jsonl`]).
    pub jsonl_path: Option<PathBuf>,
    /// Event-ring transport capacity override; 0 ⇒
    /// [`DEFAULT_RING_CAPACITY`].
    pub ring_capacity: usize,
    /// Out-param: the scheduler publishes the realized metrics bind
    /// address here (resolves port 0 for tests and harnesses).
    pub bound: Option<Arc<OnceLock<SocketAddr>>>,
}

impl TelemetryConfig {
    /// Telemetry with the `/metrics` endpoint on `addr`.
    pub fn serve(addr: impl Into<String>) -> Self {
        TelemetryConfig { metrics_addr: Some(addr.into()), ..Default::default() }
    }

    /// Add a JSONL event tail.
    pub fn with_jsonl(mut self, path: impl Into<PathBuf>) -> Self {
        self.jsonl_path = Some(path.into());
        self
    }

    /// Override the event-ring transport capacity.
    pub fn with_ring_capacity(mut self, cap: usize) -> Self {
        self.ring_capacity = cap;
        self
    }

    /// Register a cell to receive the realized metrics bind address.
    pub fn with_bound_cell(mut self, cell: Arc<OnceLock<SocketAddr>>) -> Self {
        self.bound = Some(cell);
        self
    }

    /// True when any live exporter is enabled (the scheduler only builds
    /// the registry/exporter threads in that case).
    pub fn is_active(&self) -> bool {
        self.metrics_addr.is_some() || self.jsonl_path.is_some()
    }

    /// Effective ring transport capacity.
    pub fn effective_ring_capacity(&self) -> usize {
        if self.ring_capacity == 0 {
            DEFAULT_RING_CAPACITY
        } else {
            self.ring_capacity
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_defaults_are_inert() {
        let cfg = TelemetryConfig::default();
        assert!(!cfg.is_active());
        assert_eq!(cfg.effective_ring_capacity(), DEFAULT_RING_CAPACITY);
    }

    #[test]
    fn builders_activate_exporters() {
        let cfg = TelemetryConfig::serve("127.0.0.1:0").with_jsonl("/tmp/x.jsonl");
        assert!(cfg.is_active());
        assert_eq!(cfg.metrics_addr.as_deref(), Some("127.0.0.1:0"));
        assert!(cfg.jsonl_path.is_some());
        assert_eq!(
            TelemetryConfig::default().with_ring_capacity(128).effective_ring_capacity(),
            128
        );
    }
}
