//! Pull-model metrics: free counter reads rendered as Prometheus text.
//!
//! The registry holds type-erased views of everything already
//! instrumented — every monitored stream's [`crate::queue::QueueCounters`]
//! (whose monotonic head/tail indices *are* the pop/push counters, so a
//! scrape is a handful of Relaxed loads) and every elastic stage — plus a
//! small [`MetricsShared`] block the controller refreshes once per
//! control tick (ρ, λ, μ, budget, converged rates). **A scrape never
//! copy-and-zeros anything**: the monitor's and controller's delta
//! sampling is untouched, and the data path pays zero new atomics.
//!
//! Exposed metrics (all prefixed `sf_`):
//!
//! | metric | type | labels |
//! |---|---|---|
//! | `sf_stream_pushes_total` | counter | `stream` |
//! | `sf_stream_pops_total` | counter | `stream` |
//! | `sf_stream_read_blocked_ns_total` | counter | `stream` |
//! | `sf_stream_write_blocked_ns_total` | counter | `stream` |
//! | `sf_stream_occupancy` | gauge | `stream` |
//! | `sf_stream_capacity` | gauge | `stream` |
//! | `sf_stream_closed` | gauge | `stream` |
//! | `sf_queue_segments` | gauge | `stream` |
//! | `sf_segment_allocs_total` | counter | `stream` |
//! | `sf_stream_rate_mbps` | gauge | `stream`, `end` |
//! | `sf_stage_replicas` | gauge | `stage` |
//! | `sf_stage_rho` | gauge | `stage` |
//! | `sf_stage_lambda_items_per_sec` | gauge | `stage` |
//! | `sf_stage_mu_items_per_sec` | gauge | `stage` |
//! | `sf_worker_budget` | gauge | — |
//! | `sf_net_frames_total` | counter | `edge` |
//! | `sf_net_bytes_total` | counter | `edge` |
//! | `sf_net_reconnects_total` | counter | `edge` |
//! | `sf_net_in_flight` | gauge | `edge` |
//! | `sf_net_poisoned` | gauge | `edge` |
//! | `sf_events_dropped_total` | counter | — |
//! | `sf_faults_total` | counter | — |
//! | `sf_degradation_level` | gauge | — |
//! | `sf_items_shed_total` | counter | — |
//! | `sf_analysis_warnings` | gauge | — |
//! | `sf_build_info` | gauge | `version` |
//!
//! Conservation invariant (tested in `tests/telemetry.rs`): for every
//! stream, `pushes == pops + occupancy` holds *within a single scrape*
//! whenever the stream is quiescent, and the final totals equal
//! `RunReport::stream_totals` exactly.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::elastic::ElasticStage;
use crate::monitor::QueueEnd;
use crate::queue::MonitorHandle;
use crate::topology::StreamId;

use super::ring::EventRing;

/// Per-stage gauge block (f64 bit-patterns; NaN = not yet observed).
struct StageGauges {
    rho: AtomicU64,
    lambda: AtomicU64,
    mu: AtomicU64,
}

impl StageGauges {
    fn new() -> Self {
        let nan = f64::NAN.to_bits();
        StageGauges {
            rho: AtomicU64::new(nan),
            lambda: AtomicU64::new(nan),
            mu: AtomicU64::new(nan),
        }
    }
}

/// The controller-refreshed half of the metrics plane: a fixed block of
/// atomics the control thread stores into once per tick and scrapes read
/// without coordination.
pub struct MetricsShared {
    /// Coordinated worker budget; -1 = unlimited / no controller.
    budget: AtomicI64,
    /// One gauge block per elastic stage, in topology declaration order.
    stages: Vec<StageGauges>,
    /// Latest converged rate per (stream, end), MB/s.
    rates: Mutex<BTreeMap<(usize, &'static str), f64>>,
    /// Supervision faults observed (panics, escalations, deadline aborts).
    faults: AtomicU64,
    /// Highest degradation level currently in force across shedders.
    shed_level: AtomicU64,
    /// Lifetime items deliberately shed across all sources.
    shed_total: AtomicU64,
    /// Warnings the pre-run graph analyzer attached to this run.
    analysis_warnings: AtomicU64,
}

impl std::fmt::Debug for MetricsShared {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MetricsShared")
            .field("budget", &self.budget.load(Ordering::Relaxed))
            .field("stages", &self.stages.len())
            .finish()
    }
}

impl MetricsShared {
    pub fn new(num_stages: usize) -> Arc<Self> {
        Arc::new(MetricsShared {
            budget: AtomicI64::new(-1),
            stages: (0..num_stages).map(|_| StageGauges::new()).collect(),
            rates: Mutex::new(BTreeMap::new()),
            faults: AtomicU64::new(0),
            shed_level: AtomicU64::new(0),
            shed_total: AtomicU64::new(0),
            analysis_warnings: AtomicU64::new(0),
        })
    }

    /// Scheduler-side: record the pre-run analyzer's warning count once,
    /// before the first scrape window opens.
    pub fn set_analysis_warnings(&self, n: u64) {
        self.analysis_warnings.store(n, Ordering::Relaxed);
    }

    /// Warnings the pre-run analyzer attached to this run.
    pub fn analysis_warnings(&self) -> u64 {
        self.analysis_warnings.load(Ordering::Relaxed)
    }

    /// Controller-side: count supervision faults as they are tailed.
    pub fn inc_faults(&self, n: u64) {
        self.faults.fetch_add(n, Ordering::Relaxed);
    }

    /// Supervision faults observed so far.
    pub fn faults_total(&self) -> u64 {
        self.faults.load(Ordering::Relaxed)
    }

    /// Controller-side: publish the degradation state (highest level in
    /// force, lifetime shed count summed across sources).
    pub fn set_shed(&self, level: u8, total: u64) {
        self.shed_level.store(level as u64, Ordering::Relaxed);
        self.shed_total.store(total, Ordering::Relaxed);
    }

    /// Current `(degradation level, items shed)`.
    pub fn shed(&self) -> (u8, u64) {
        (
            self.shed_level.load(Ordering::Relaxed) as u8,
            self.shed_total.load(Ordering::Relaxed),
        )
    }

    /// Controller-side: publish the coordinated budget (`None` ⇒ unlimited).
    pub fn set_budget(&self, budget: Option<usize>) {
        self.budget.store(budget.map(|b| b as i64).unwrap_or(-1), Ordering::Relaxed);
    }

    /// Current budget, if one is in force.
    pub fn budget(&self) -> Option<usize> {
        let b = self.budget.load(Ordering::Relaxed);
        (b >= 0).then_some(b as usize)
    }

    /// Controller-side: publish one stage's per-tick observation.
    pub fn set_stage(&self, i: usize, rho: f64, lambda: f64, mu: f64) {
        if let Some(g) = self.stages.get(i) {
            g.rho.store(rho.to_bits(), Ordering::Relaxed);
            g.lambda.store(lambda.to_bits(), Ordering::Relaxed);
            g.mu.store(mu.to_bits(), Ordering::Relaxed);
        }
    }

    /// One stage's (ρ, λ, μ), if the controller has observed it.
    pub fn stage(&self, i: usize) -> Option<(f64, f64, f64)> {
        let g = self.stages.get(i)?;
        let rho = f64::from_bits(g.rho.load(Ordering::Relaxed));
        let lambda = f64::from_bits(g.lambda.load(Ordering::Relaxed));
        let mu = f64::from_bits(g.mu.load(Ordering::Relaxed));
        (!rho.is_nan() || !lambda.is_nan() || !mu.is_nan()).then_some((rho, lambda, mu))
    }

    /// Controller-side: publish a converged monitor estimate.
    pub fn set_rate(&self, stream: StreamId, end: QueueEnd, mbps: f64) {
        let key = (stream.0, match end {
            QueueEnd::Head => "head",
            QueueEnd::Tail => "tail",
        });
        // A scrape or tick must survive a panicked peer: take the data
        // through the poison.
        self.rates.lock().unwrap_or_else(|e| e.into_inner()).insert(key, mbps);
    }

    fn rates_snapshot(&self) -> BTreeMap<(usize, &'static str), f64> {
        self.rates.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }
}

struct StreamEntry {
    id: StreamId,
    label: String,
    handle: Arc<dyn MonitorHandle>,
}

/// The scrape surface: enumerates streams and stages once at wiring time,
/// renders Prometheus text on demand.
pub struct MetricsRegistry {
    streams: Vec<StreamEntry>,
    stages: Vec<Arc<dyn ElasticStage>>,
    net_edges: Vec<Arc<crate::net::NetEdgeStats>>,
    shared: Arc<MetricsShared>,
    ring: Option<Arc<EventRing>>,
}

impl std::fmt::Debug for MetricsRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MetricsRegistry")
            .field("streams", &self.streams.len())
            .field("stages", &self.stages.len())
            .finish()
    }
}

impl MetricsRegistry {
    pub fn new(shared: Arc<MetricsShared>) -> Self {
        MetricsRegistry {
            streams: Vec::new(),
            stages: Vec::new(),
            net_edges: Vec::new(),
            shared,
            ring: None,
        }
    }

    /// A registry with no controller behind it (bench/test harnesses).
    pub fn standalone() -> Self {
        MetricsRegistry::new(MetricsShared::new(0))
    }

    /// The controller-refreshed gauge block.
    pub fn shared(&self) -> &Arc<MetricsShared> {
        &self.shared
    }

    /// Register one monitored stream (its counters are read live on every
    /// scrape; never sampled-and-zeroed).
    pub fn add_stream(&mut self, id: StreamId, label: impl Into<String>, handle: Arc<dyn MonitorHandle>) {
        self.streams.push(StreamEntry { id, label: label.into(), handle });
    }

    /// Register one elastic stage (replica gauge).
    pub fn add_stage(&mut self, stage: Arc<dyn ElasticStage>) {
        self.stages.push(stage);
    }

    /// Register one network-backed edge's transport counters (scraped
    /// live, same pull model as streams).
    pub fn add_net_edge(&mut self, stats: Arc<crate::net::NetEdgeStats>) {
        self.net_edges.push(stats);
    }

    /// Attach the control-plane event ring (dropped-event audit metric).
    pub fn set_ring(&mut self, ring: Arc<EventRing>) {
        self.ring = Some(ring);
    }

    /// Render the full scrape in Prometheus text exposition format 0.0.4.
    pub fn render(&self) -> String {
        let mut out = String::with_capacity(4096);

        self.counter_section(&mut out, "sf_stream_pushes_total",
            "Items pushed into the stream since start.",
            |h| h.counters().total_pushes());
        self.counter_section(&mut out, "sf_stream_pops_total",
            "Items popped from the stream since start.",
            |h| h.counters().total_pops());
        self.counter_section(&mut out, "sf_stream_read_blocked_ns_total",
            "Nanoseconds the consumer spent blocked on an empty stream.",
            |h| h.counters().total_read_blocked_ns());
        self.counter_section(&mut out, "sf_stream_write_blocked_ns_total",
            "Nanoseconds the producer spent blocked on a full stream.",
            |h| h.counters().total_write_blocked_ns());
        self.gauge_section(&mut out, "sf_stream_occupancy",
            "Items currently in flight in the stream.",
            |h| h.len() as f64);
        self.gauge_section(&mut out, "sf_stream_capacity",
            "Current stream capacity in items.",
            |h| h.capacity() as f64);
        self.gauge_section(&mut out, "sf_stream_closed",
            "1 once the producer has closed the stream.",
            |h| if h.is_closed() { 1.0 } else { 0.0 });
        self.gauge_section(&mut out, "sf_queue_segments",
            "Segments the queue currently owns (live chain + free list); \
             0 for the contiguous-ring backend. Watch it fall after a \
             shrink to audit memory actually returned.",
            |h| h.counters().segments() as f64);
        self.counter_section(&mut out, "sf_segment_allocs_total",
            "Segment allocations that hit the allocator (free-list reuse \
             does not count); 0 for the contiguous-ring backend.",
            |h| h.counters().segment_allocs());

        // Converged monitor estimates, keyed back to stream labels.
        let rates = self.shared.rates_snapshot();
        if !rates.is_empty() {
            header(&mut out, "sf_stream_rate_mbps",
                "Latest converged non-blocking rate estimate (MB/s).", "gauge");
            for ((sid, end), mbps) in &rates {
                let label = self
                    .streams
                    .iter()
                    .find(|s| s.id.0 == *sid)
                    .map(|s| s.label.as_str())
                    .unwrap_or("?");
                let _ = writeln!(
                    out,
                    "sf_stream_rate_mbps{{stream=\"{}\",end=\"{}\"}} {}",
                    escape_label(label),
                    end,
                    fmt_value(*mbps)
                );
            }
        }

        if !self.stages.is_empty() {
            header(&mut out, "sf_stage_replicas", "Active replica lanes of the stage.", "gauge");
            for st in &self.stages {
                let _ = writeln!(
                    out,
                    "sf_stage_replicas{{stage=\"{}\"}} {}",
                    escape_label(st.stage_name()),
                    st.replicas()
                );
            }
            self.stage_gauge_section(&mut out, "sf_stage_rho",
                "Utilization estimate lambda / (replicas * mu).", |g| g.0);
            self.stage_gauge_section(&mut out, "sf_stage_lambda_items_per_sec",
                "Arrival rate into the stage (items/s, EWMA).", |g| g.1);
            self.stage_gauge_section(&mut out, "sf_stage_mu_items_per_sec",
                "Per-replica service rate (items/s, EWMA).", |g| g.2);
        }

        if let Some(b) = self.shared.budget() {
            header(&mut out, "sf_worker_budget", "Coordinated replica budget in force.", "gauge");
            let _ = writeln!(out, "sf_worker_budget {b}");
        }

        if !self.net_edges.is_empty() {
            self.net_counter_section(&mut out, "sf_net_frames_total",
                "Data frames carried over the network edge.", |e| e.frames());
            self.net_counter_section(&mut out, "sf_net_bytes_total",
                "Wire bytes carried over the network edge (frames incl. headers).",
                |e| e.bytes());
            self.net_counter_section(&mut out, "sf_net_reconnects_total",
                "Dial attempts beyond the first on the network edge.",
                |e| e.reconnects());
            self.net_gauge_section(&mut out, "sf_net_in_flight",
                "Items the remote peer pushed that have not yet landed in the \
                 local queue (on the wire or in the decode backlog).",
                |e| e.in_flight());
            self.net_gauge_section(&mut out, "sf_net_poisoned",
                "1 once the edge terminated on a transport fault or remote poison.",
                |e| if e.is_poisoned() { 1 } else { 0 });
        }
        if let Some(ring) = &self.ring {
            header(&mut out, "sf_events_dropped_total",
                "Control-plane events lost to ring overflow (audited).", "counter");
            let _ = writeln!(out, "sf_events_dropped_total {}", ring.dropped());
        }

        header(&mut out, "sf_faults_total",
            "Supervision faults observed (panics, escalations, aborts).", "counter");
        let _ = writeln!(out, "sf_faults_total {}", self.shared.faults_total());
        let (level, shed) = self.shared.shed();
        header(&mut out, "sf_degradation_level",
            "Highest load-shedding level currently in force (0 = full fidelity).",
            "gauge");
        let _ = writeln!(out, "sf_degradation_level {level}");
        header(&mut out, "sf_items_shed_total",
            "Items deliberately dropped by degraded sources.", "counter");
        let _ = writeln!(out, "sf_items_shed_total {shed}");
        header(&mut out, "sf_analysis_warnings",
            "Warnings from the pre-run graph analyzer (rules A1-A5).", "gauge");
        let _ = writeln!(out, "sf_analysis_warnings {}", self.shared.analysis_warnings());

        header(&mut out, "sf_build_info", "Build metadata (constant 1).", "gauge");
        let _ = writeln!(out, "sf_build_info{{version=\"{}\"}} 1", crate::version());
        out
    }

    fn counter_section(
        &self,
        out: &mut String,
        name: &str,
        help: &str,
        read: impl Fn(&dyn MonitorHandle) -> u64,
    ) {
        if self.streams.is_empty() {
            return;
        }
        header(out, name, help, "counter");
        for s in &self.streams {
            let _ = writeln!(
                out,
                "{name}{{stream=\"{}\"}} {}",
                escape_label(&s.label),
                read(s.handle.as_ref())
            );
        }
    }

    fn gauge_section(
        &self,
        out: &mut String,
        name: &str,
        help: &str,
        read: impl Fn(&dyn MonitorHandle) -> f64,
    ) {
        if self.streams.is_empty() {
            return;
        }
        header(out, name, help, "gauge");
        for s in &self.streams {
            let _ = writeln!(
                out,
                "{name}{{stream=\"{}\"}} {}",
                escape_label(&s.label),
                fmt_value(read(s.handle.as_ref()))
            );
        }
    }

    fn net_counter_section(
        &self,
        out: &mut String,
        name: &str,
        help: &str,
        read: impl Fn(&crate::net::NetEdgeStats) -> u64,
    ) {
        header(out, name, help, "counter");
        for e in &self.net_edges {
            let _ = writeln!(
                out,
                "{name}{{edge=\"{}\"}} {}",
                escape_label(e.label()),
                read(e.as_ref())
            );
        }
    }

    fn net_gauge_section(
        &self,
        out: &mut String,
        name: &str,
        help: &str,
        read: impl Fn(&crate::net::NetEdgeStats) -> u64,
    ) {
        header(out, name, help, "gauge");
        for e in &self.net_edges {
            let _ = writeln!(
                out,
                "{name}{{edge=\"{}\"}} {}",
                escape_label(e.label()),
                read(e.as_ref())
            );
        }
    }

    fn stage_gauge_section(
        &self,
        out: &mut String,
        name: &str,
        help: &str,
        pick: impl Fn((f64, f64, f64)) -> f64,
    ) {
        let observed: Vec<(usize, (f64, f64, f64))> = (0..self.stages.len())
            .filter_map(|i| self.shared.stage(i).map(|g| (i, g)))
            .collect();
        if observed.is_empty() {
            return;
        }
        header(out, name, help, "gauge");
        for (i, g) in observed {
            let v = pick(g);
            if v.is_nan() {
                continue;
            }
            let _ = writeln!(
                out,
                "{name}{{stage=\"{}\"}} {}",
                escape_label(self.stages[i].stage_name()),
                fmt_value(v)
            );
        }
    }
}

fn header(out: &mut String, name: &str, help: &str, mtype: &str) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} {mtype}");
}

/// Prometheus label-value escaping: backslash, double-quote, newline.
fn escape_label(s: &str) -> String {
    let mut e = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => e.push_str("\\\\"),
            '"' => e.push_str("\\\""),
            '\n' => e.push_str("\\n"),
            c => e.push(c),
        }
    }
    e
}

/// Prometheus sample values: plain decimal, no exponent surprises for
/// the common magnitudes; counters pass through as integers upstream.
fn fmt_value(v: f64) -> String {
    if v == v.trunc() && v.abs() < 9.0e15 {
        format!("{}", v as i64)
    } else {
        format!("{v:.6}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::queue::{instrumented, StreamConfig};

    #[test]
    fn scrape_reads_counters_without_disturbing_them() {
        let (q, h) = instrumented::<u64>(&StreamConfig::default());
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        let _ = q.pop();
        let mut reg = MetricsRegistry::standalone();
        reg.add_stream(StreamId(0), "a.0 -> b.0", h.clone());
        let text = reg.render();
        assert!(text.contains("sf_stream_pushes_total{stream=\"a.0 -> b.0\"} 2"), "{text}");
        assert!(text.contains("sf_stream_pops_total{stream=\"a.0 -> b.0\"} 1"), "{text}");
        assert!(text.contains("sf_stream_occupancy{stream=\"a.0 -> b.0\"} 1"), "{text}");
        // Scraping twice must not zero anything (pull model, no deltas).
        let again = reg.render();
        assert!(again.contains("sf_stream_pushes_total{stream=\"a.0 -> b.0\"} 2"), "{again}");
        assert_eq!(h.counters().total_pushes(), 2);
        // Ring backend: segment metrics render as zero, not absent.
        assert!(text.contains("sf_queue_segments{stream=\"a.0 -> b.0\"} 0"), "{text}");
        assert!(text.contains("sf_segment_allocs_total{stream=\"a.0 -> b.0\"} 0"), "{text}");
    }

    #[test]
    fn segment_metrics_render_for_segmented_streams() {
        use crate::queue::{build, QueueBackend};
        let cfg = StreamConfig::default()
            .with_backend(QueueBackend::Segmented)
            .with_capacity(crate::queue::SEG_SLOTS * 2);
        let (q, h) = build::<u64>(&cfg);
        for i in 0..(crate::queue::SEG_SLOTS as u64 + 1) {
            q.try_push(i).unwrap();
        }
        let mut reg = MetricsRegistry::standalone();
        reg.add_stream(StreamId(0), "seg", h.clone());
        let text = reg.render();
        let owned = h.counters().segments();
        let allocs = h.counters().segment_allocs();
        assert!(owned >= 2 && allocs >= 2, "crossed one boundary: {owned}/{allocs}");
        assert!(text.contains(&format!("sf_queue_segments{{stream=\"seg\"}} {owned}")), "{text}");
        assert!(
            text.contains(&format!("sf_segment_allocs_total{{stream=\"seg\"}} {allocs}")),
            "{text}"
        );
    }

    #[test]
    fn shared_gauges_round_trip_and_gate_on_observation() {
        let shared = MetricsShared::new(2);
        assert!(shared.stage(0).is_none(), "unobserved stage renders nothing");
        shared.set_stage(0, 0.8, 1000.0, 500.0);
        assert_eq!(shared.stage(0), Some((0.8, 1000.0, 500.0)));
        assert!(shared.stage(1).is_none());
        assert_eq!(shared.budget(), None);
        shared.set_budget(Some(6));
        assert_eq!(shared.budget(), Some(6));
        shared.set_budget(None);
        assert_eq!(shared.budget(), None);
    }

    #[test]
    fn fault_and_shed_metrics_render_from_zero() {
        let reg = MetricsRegistry::standalone();
        let text = reg.render();
        assert!(text.contains("sf_faults_total 0"), "{text}");
        assert!(text.contains("sf_degradation_level 0"), "{text}");
        assert!(text.contains("sf_items_shed_total 0"), "{text}");
        assert!(text.contains("sf_analysis_warnings 0"), "{text}");
        reg.shared().inc_faults(2);
        reg.shared().set_shed(3, 4096);
        reg.shared().set_analysis_warnings(5);
        let text = reg.render();
        assert!(text.contains("sf_faults_total 2"), "{text}");
        assert!(text.contains("sf_degradation_level 3"), "{text}");
        assert!(text.contains("sf_items_shed_total 4096"), "{text}");
        assert!(text.contains("sf_analysis_warnings 5"), "{text}");
        assert_eq!(reg.shared().shed(), (3, 4096));
    }

    #[test]
    fn label_escaping_is_prometheus_safe() {
        assert_eq!(escape_label(r#"a"b\c"#), r#"a\"b\\c"#);
        assert_eq!(escape_label("x\ny"), "x\\ny");
    }

    #[test]
    fn net_edge_metrics_render_with_edge_label() {
        let stats = crate::net::NetEdgeStats::new("feed:0");
        stats.add_sent(10);
        stats.note_frame(120);
        stats.set_remote(10, 0);
        stats.add_received(7);
        let mut reg = MetricsRegistry::standalone();
        reg.add_net_edge(stats.clone());
        let text = reg.render();
        assert!(text.contains("sf_net_frames_total{edge=\"feed:0\"} 1"), "{text}");
        assert!(text.contains("sf_net_bytes_total{edge=\"feed:0\"} 120"), "{text}");
        assert!(text.contains("sf_net_in_flight{edge=\"feed:0\"} 3"), "{text}");
        assert!(text.contains("sf_net_poisoned{edge=\"feed:0\"} 0"), "{text}");
        stats.poison_with("net_source:feed:0", "socket dropped");
        let text = reg.render();
        assert!(text.contains("sf_net_poisoned{edge=\"feed:0\"} 1"), "{text}");
    }

    #[test]
    fn dropped_counter_is_exposed_when_a_ring_is_attached() {
        let ring = Arc::new(EventRing::new(2));
        for k in 0..5 {
            ring.emit(crate::telemetry::ControlEvent::Note { at_ns: k, note: "x".into() });
        }
        let mut reg = MetricsRegistry::standalone();
        reg.set_ring(ring);
        let text = reg.render();
        assert!(text.contains("sf_events_dropped_total 3"), "{text}");
    }
}
