//! Bounded lock-free structured event ring: the control plane's single
//! audit channel.
//!
//! The elastic controller used to accumulate scaling history in ad-hoc
//! `Vec`s that were only readable after the run. The ring splits that
//! into two halves with different guarantees:
//!
//! * a **bounded SPSC transport** — the controller (the unique producer)
//!   publishes [`ControlEvent`]s with one Release store each, and live
//!   exporters (the JSONL tailer, a metrics scrape, `snapshot_report`)
//!   drain it concurrently with the run;
//! * an **unbounded journal** behind a mutex — every drained event is
//!   appended here, so the end-of-run [`ControlPlaneReport`] timeline is
//!   exactly as complete as the old `Vec` path was.
//!
//! Overflow is *audited, never silent*: when a burst outruns the
//! transport between two drains, the event is counted in
//! [`EventRing::dropped`] — surfaced in `RunReport::events_dropped` and
//! as the `sf_events_dropped_total` metric. The controller drains its own
//! ring at the end of every control tick, so drops only happen when a
//! single tick emits more events than the ring holds.
//!
//! [`ControlPlaneReport`]: crate::elastic::ControlPlaneReport

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::config::Json;
use crate::elastic::{ElasticAction, ElasticEvent};
use crate::monitor::QueueEnd;
use crate::topology::StreamId;

/// Why a wanted scale-up was withheld by [`crate::elastic::coordinate`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GateReason {
    /// The stage's own lanes were starved past the threshold (§IV
    /// validity: adding replicas to a starved stage is noise).
    Starved,
    /// The downstream edge was write-blocked past the threshold: more
    /// replicas would only pile onto a saturated consumer.
    DownstreamBlocked,
    /// The coordinated worker budget trimmed the claim.
    Budget,
}

impl GateReason {
    pub fn as_str(&self) -> &'static str {
        match self {
            GateReason::Starved => "starved",
            GateReason::DownstreamBlocked => "downstream-blocked",
            GateReason::Budget => "budget",
        }
    }
}

/// Which end of a stream a blocked span was recorded on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BlockEnd {
    /// Consumer side (`read_blocked_ns`): the stream starved its reader.
    Read,
    /// Producer side (`write_blocked_ns`): the stream backpressured its
    /// writer.
    Write,
}

impl BlockEnd {
    pub fn as_str(&self) -> &'static str {
        match self {
            BlockEnd::Read => "read",
            BlockEnd::Write => "write",
        }
    }
}

/// One structured control-plane event. See [`ControlEvent::to_json`] for
/// the stable JSONL wire schema (documented in [`crate::telemetry::jsonl`]).
#[derive(Debug, Clone)]
pub enum ControlEvent {
    /// A realized scaling or resize decision (the classic audit event).
    Action(ElasticEvent),
    /// The coordinated worker budget changed.
    Budget { at_ns: u64, budget: usize },
    /// A free-form control-plane annotation (e.g. degraded host
    /// telemetry).
    Note { at_ns: u64, note: String },
    /// A wanted scale-up was withheld, with the reason. Emitted once per
    /// (wanted, reason) change, not every tick.
    ScaleGated { at_ns: u64, stage: String, replicas: usize, wanted: usize, reason: GateReason },
    /// A replica lane was spawned (`spawned == true`) or retired.
    Lane { at_ns: u64, stage: String, lane: usize, spawned: bool },
    /// A stream spent `dur_ns` of the last control tick blocked on one
    /// end. `at_ns` is the end of the span (the tick timestamp).
    BlockedSpan { at_ns: u64, label: String, end: BlockEnd, dur_ns: u64 },
    /// A monitor estimate converged for one stream end.
    RateConverged { at_ns: u64, stream: StreamId, end: QueueEnd, mbps: f64 },
    /// A kernel or replica lane panicked (or the run was force-closed).
    /// `lane` is `None` for plain (non-elastic) kernels and for
    /// run-level faults such as a deadline abort; `restarts` counts
    /// supervised respawns consumed so far; `escalated` marks the
    /// budget-exhausted transition to stage failure.
    Fault {
        at_ns: u64,
        target: String,
        lane: Option<usize>,
        restarts: u32,
        escalated: bool,
        message: String,
    },
    /// A stage made zero progress (no ingress pushes, no lane pops) for
    /// `epochs` consecutive control ticks while its input was still
    /// open. Emitted once per stall episode, not every tick.
    StallSuspected { at_ns: u64, stage: String, epochs: u32 },
    /// A sheddable source's degradation level changed (awstream-style
    /// load shedding). `shed_total` is the source's lifetime count of
    /// deliberately dropped items at the moment of the change.
    Shed { at_ns: u64, target: String, level: u8, shed_total: u64 },
}

impl ControlEvent {
    /// Timestamp of the event (ns on the run's [`crate::timing::TimeRef`]
    /// clock).
    pub fn at_ns(&self) -> u64 {
        match self {
            ControlEvent::Action(e) => e.at_ns,
            ControlEvent::Budget { at_ns, .. }
            | ControlEvent::Note { at_ns, .. }
            | ControlEvent::ScaleGated { at_ns, .. }
            | ControlEvent::Lane { at_ns, .. }
            | ControlEvent::BlockedSpan { at_ns, .. }
            | ControlEvent::RateConverged { at_ns, .. }
            | ControlEvent::Fault { at_ns, .. }
            | ControlEvent::StallSuspected { at_ns, .. }
            | ControlEvent::Shed { at_ns, .. } => *at_ns,
        }
    }

    /// One JSON object per event — the JSONL line schema. Every object
    /// carries `"type"` and `"at_ns"`; the rest is per-variant (see the
    /// [`crate::telemetry::jsonl`] module docs for the full schema).
    pub fn to_json(&self) -> Json {
        let mut o = std::collections::BTreeMap::new();
        o.insert("at_ns".to_string(), Json::Num(self.at_ns() as f64));
        match self {
            ControlEvent::Action(e) => {
                o.insert("type".into(), Json::Str("action".into()));
                o.insert("target".into(), Json::Str(e.target.clone()));
                let (kind, from, to) = match e.action {
                    ElasticAction::ScaleUp { from, to } => ("scale-up", from, to),
                    ElasticAction::ScaleDown { from, to } => ("scale-down", from, to),
                    ElasticAction::Resize { from, to, model } => {
                        o.insert("model".into(), Json::Str(model.to_string()));
                        ("resize", from, to)
                    }
                };
                o.insert("action".into(), Json::Str(kind.into()));
                o.insert("from".into(), Json::Num(from as f64));
                o.insert("to".into(), Json::Num(to as f64));
                o.insert("rho".into(), Json::Num(e.rho));
                o.insert("lambda_items".into(), Json::Num(e.lambda_items));
                o.insert("mu_items".into(), Json::Num(e.mu_items));
                o.insert("pressure".into(), Json::Bool(e.pressure));
                o.insert("starved_frac".into(), Json::Num(e.starved_frac));
                o.insert("backpressure_frac".into(), Json::Num(e.backpressure_frac));
            }
            ControlEvent::Budget { budget, .. } => {
                o.insert("type".into(), Json::Str("budget".into()));
                o.insert("budget".into(), Json::Num(*budget as f64));
            }
            ControlEvent::Note { note, .. } => {
                o.insert("type".into(), Json::Str("note".into()));
                o.insert("note".into(), Json::Str(note.clone()));
            }
            ControlEvent::ScaleGated { stage, replicas, wanted, reason, .. } => {
                o.insert("type".into(), Json::Str("scale-gated".into()));
                o.insert("stage".into(), Json::Str(stage.clone()));
                o.insert("replicas".into(), Json::Num(*replicas as f64));
                o.insert("wanted".into(), Json::Num(*wanted as f64));
                o.insert("reason".into(), Json::Str(reason.as_str().into()));
            }
            ControlEvent::Lane { stage, lane, spawned, .. } => {
                o.insert("type".into(), Json::Str("lane".into()));
                o.insert("stage".into(), Json::Str(stage.clone()));
                o.insert("lane".into(), Json::Num(*lane as f64));
                o.insert(
                    "event".into(),
                    Json::Str(if *spawned { "spawn" } else { "retire" }.into()),
                );
            }
            ControlEvent::BlockedSpan { label, end, dur_ns, .. } => {
                o.insert("type".into(), Json::Str("blocked-span".into()));
                o.insert("stream".into(), Json::Str(label.clone()));
                o.insert("end".into(), Json::Str(end.as_str().into()));
                o.insert("dur_ns".into(), Json::Num(*dur_ns as f64));
            }
            ControlEvent::RateConverged { stream, end, mbps, .. } => {
                o.insert("type".into(), Json::Str("rate-converged".into()));
                o.insert("stream".into(), Json::Num(stream.0 as f64));
                o.insert(
                    "end".into(),
                    Json::Str(match end {
                        QueueEnd::Head => "head",
                        QueueEnd::Tail => "tail",
                    }
                    .into()),
                );
                o.insert("mbps".into(), Json::Num(*mbps));
            }
            ControlEvent::Fault { target, lane, restarts, escalated, message, .. } => {
                o.insert("type".into(), Json::Str("fault".into()));
                o.insert("target".into(), Json::Str(target.clone()));
                if let Some(lane) = lane {
                    o.insert("lane".into(), Json::Num(*lane as f64));
                }
                o.insert("restarts".into(), Json::Num(*restarts as f64));
                o.insert("escalated".into(), Json::Bool(*escalated));
                o.insert("message".into(), Json::Str(message.clone()));
            }
            ControlEvent::StallSuspected { stage, epochs, .. } => {
                o.insert("type".into(), Json::Str("stall-suspected".into()));
                o.insert("stage".into(), Json::Str(stage.clone()));
                o.insert("epochs".into(), Json::Num(*epochs as f64));
            }
            ControlEvent::Shed { target, level, shed_total, .. } => {
                o.insert("type".into(), Json::Str("shed".into()));
                o.insert("target".into(), Json::Str(target.clone()));
                o.insert("level".into(), Json::Num(*level as f64));
                o.insert("shed_total".into(), Json::Num(*shed_total as f64));
            }
        }
        Json::Obj(o)
    }
}

/// Bounded SPSC event transport + unbounded drained journal.
///
/// Concurrency contract (mirrors the data-plane queue's reasoning):
///
/// * **one producer** — only the control thread calls [`EventRing::emit`];
/// * **serialized consumers** — every drain path ([`EventRing::sync`] and
///   its callers) runs under the journal mutex, so at most one consumer
///   touches `head`/slots at a time;
/// * slot hand-off is published by the Release store of `tail` (producer)
///   and re-owned by the Release store of `head` (consumer), each read
///   with Acquire on the opposite side.
pub struct EventRing {
    slots: Box<[UnsafeCell<Option<ControlEvent>>]>,
    /// Events published (monotonic; producer-owned).
    tail: AtomicU64,
    /// Events drained into the journal (monotonic; consumer-owned).
    head: AtomicU64,
    /// Events refused because the transport was full (audited overflow).
    dropped: AtomicU64,
    /// Everything ever drained, in publish order.
    journal: Mutex<Vec<ControlEvent>>,
}

// SAFETY: slot access is disciplined as documented on the type — the
// unique producer writes a slot only while it is outside [head, tail),
// and consumers (serialized by the journal mutex) read it only once the
// tail Release store has published it.
unsafe impl Send for EventRing {}
// SAFETY: same argument as Send above — the head/tail handshake plus the
// journal mutex serialize every slot access across threads.
unsafe impl Sync for EventRing {}

impl std::fmt::Debug for EventRing {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("EventRing")
            .field("capacity", &self.slots.len())
            .field("published", &self.tail.load(Ordering::Relaxed))
            .field("drained", &self.head.load(Ordering::Relaxed))
            .field("dropped", &self.dropped.load(Ordering::Relaxed))
            .finish()
    }
}

impl EventRing {
    /// A ring holding at most `capacity` undrained events (min 2).
    pub fn new(capacity: usize) -> Self {
        let cap = capacity.max(2);
        let slots: Vec<UnsafeCell<Option<ControlEvent>>> =
            (0..cap).map(|_| UnsafeCell::new(None)).collect();
        EventRing {
            slots: slots.into_boxed_slice(),
            tail: AtomicU64::new(0),
            head: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            journal: Mutex::new(Vec::new()),
        }
    }

    /// Transport capacity in events.
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Publish one event. **Producer-only** (the control thread). Returns
    /// `false` — and bumps the dropped counter — when the transport is
    /// full; the event is discarded but never silently (see
    /// [`EventRing::dropped`]).
    pub fn emit(&self, ev: ControlEvent) -> bool {
        let tail = self.tail.load(Ordering::Relaxed);
        let head = self.head.load(Ordering::Acquire);
        if tail.wrapping_sub(head) >= self.slots.len() as u64 {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        let idx = (tail % self.slots.len() as u64) as usize;
        // SAFETY: slot `idx` is outside [head, tail) — the consumer has
        // re-owned it to us via the head Release store read above.
        unsafe { *self.slots[idx].get() = Some(ev) };
        self.tail.store(tail.wrapping_add(1), Ordering::Release);
        true
    }

    /// Events refused so far because the transport was full.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Drain every published event into the journal. Safe from any
    /// thread; concurrent callers serialize on the journal mutex.
    pub fn sync(&self) {
        // Poison-tolerant: the journal is plain data, and a reader that
        // panicked mid-drain must not cascade into every later drain
        // (faults are exactly when this journal matters most).
        let mut journal = self.journal.lock().unwrap_or_else(|e| e.into_inner());
        self.drain_into(&mut journal);
    }

    fn drain_into(&self, journal: &mut Vec<ControlEvent>) {
        let tail = self.tail.load(Ordering::Acquire);
        let mut head = self.head.load(Ordering::Relaxed);
        while head != tail {
            let idx = (head % self.slots.len() as u64) as usize;
            // SAFETY: slot `idx` is inside [head, tail) — published by
            // the tail Release store, and ours exclusively because every
            // consumer holds the journal mutex.
            if let Some(ev) = unsafe { (*self.slots[idx].get()).take() } {
                journal.push(ev);
            }
            head = head.wrapping_add(1);
            self.head.store(head, Ordering::Release);
        }
    }

    /// Number of events in the journal right now (drains first).
    pub fn journal_len(&self) -> usize {
        let mut journal = self.journal.lock().unwrap_or_else(|e| e.into_inner());
        self.drain_into(&mut journal);
        journal.len()
    }

    /// Drain, then clone the journal suffix starting at `cursor`.
    /// Returns the events and the new cursor — the JSONL tailer's
    /// incremental read.
    pub fn read_from(&self, cursor: usize) -> (Vec<ControlEvent>, usize) {
        let mut journal = self.journal.lock().unwrap_or_else(|e| e.into_inner());
        self.drain_into(&mut journal);
        let start = cursor.min(journal.len());
        (journal[start..].to_vec(), journal.len())
    }

    /// Drain, then clone the full journal (the report builder's read).
    pub fn snapshot(&self) -> Vec<ControlEvent> {
        let mut journal = self.journal.lock().unwrap_or_else(|e| e.into_inner());
        self.drain_into(&mut journal);
        journal.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn note(k: u64) -> ControlEvent {
        ControlEvent::Note { at_ns: k, note: format!("n{k}") }
    }

    #[test]
    fn ring_preserves_publish_order() {
        let ring = EventRing::new(16);
        for k in 0..10 {
            assert!(ring.emit(note(k)));
        }
        let got = ring.snapshot();
        assert_eq!(got.len(), 10);
        for (k, ev) in got.iter().enumerate() {
            assert_eq!(ev.at_ns(), k as u64);
        }
        assert_eq!(ring.dropped(), 0);
    }

    #[test]
    fn overflow_is_counted_not_silent() {
        let ring = EventRing::new(8);
        let mut accepted = 0;
        for k in 0..20 {
            if ring.emit(note(k)) {
                accepted += 1;
            }
        }
        assert_eq!(accepted, 8);
        assert_eq!(ring.dropped(), 12);
        let got = ring.snapshot();
        assert_eq!(got.len(), 8, "transport keeps the oldest burst");
        assert_eq!(got[0].at_ns(), 0);
        assert_eq!(got[7].at_ns(), 7);
    }

    #[test]
    fn drain_between_bursts_prevents_drops() {
        let ring = EventRing::new(4);
        for round in 0..5u64 {
            for k in 0..4 {
                assert!(ring.emit(note(round * 4 + k)));
            }
            ring.sync();
        }
        assert_eq!(ring.dropped(), 0);
        assert_eq!(ring.journal_len(), 20);
    }

    #[test]
    fn incremental_reads_tile_the_journal() {
        let ring = EventRing::new(32);
        for k in 0..6 {
            ring.emit(note(k));
        }
        let (a, cur) = ring.read_from(0);
        assert_eq!(a.len(), 6);
        for k in 6..9 {
            ring.emit(note(k));
        }
        let (b, cur2) = ring.read_from(cur);
        assert_eq!(b.len(), 3);
        assert_eq!(b[0].at_ns(), 6);
        assert_eq!(cur2, 9);
        let (c, _) = ring.read_from(cur2);
        assert!(c.is_empty());
    }

    #[test]
    fn restart_storm_overflow_is_audited() {
        // A supervision storm — one tick's worth of lane faults and
        // respawns far beyond the transport capacity — must keep the
        // oldest burst and count every refused event, never silently
        // truncate the fault timeline.
        let ring = EventRing::new(4);
        let mut accepted = 0u64;
        for k in 0..16u64 {
            let ok = if k % 2 == 0 {
                ring.emit(ControlEvent::Fault {
                    at_ns: k,
                    target: "work".into(),
                    lane: Some((k / 2) as usize),
                    restarts: (k / 2) as u32,
                    escalated: false,
                    message: "lane panicked".into(),
                })
            } else {
                ring.emit(ControlEvent::Lane {
                    at_ns: k,
                    stage: "work".into(),
                    lane: (k / 2) as usize,
                    spawned: true,
                })
            };
            if ok {
                accepted += 1;
            }
        }
        assert_eq!(accepted, 4);
        assert_eq!(ring.dropped(), 12, "every refused event must be counted");
        let got = ring.snapshot();
        assert_eq!(got.len(), 4);
        assert!(matches!(got[0], ControlEvent::Fault { at_ns: 0, .. }));
    }

    #[test]
    fn every_variant_serializes_to_a_json_object() {
        let evs = vec![
            ControlEvent::Action(ElasticEvent {
                at_ns: 1,
                target: "work".into(),
                action: ElasticAction::ScaleUp { from: 1, to: 3 },
                rho: 2.5,
                lambda_items: 1000.0,
                mu_items: 400.0,
                pressure: true,
                starved_frac: 0.0,
                backpressure_frac: 0.5,
            }),
            ControlEvent::Action(ElasticEvent {
                at_ns: 2,
                target: "work".into(),
                action: ElasticAction::Resize { from: 256, to: 1024, model: "m/m/1" },
                rho: 0.7,
                lambda_items: 0.0,
                mu_items: 0.0,
                pressure: false,
                starved_frac: 0.0,
                backpressure_frac: 0.0,
            }),
            ControlEvent::Budget { at_ns: 3, budget: 6 },
            ControlEvent::Note { at_ns: 4, note: "host \"load\"\nunavailable".into() },
            ControlEvent::ScaleGated {
                at_ns: 5,
                stage: "work".into(),
                replicas: 2,
                wanted: 4,
                reason: GateReason::Starved,
            },
            ControlEvent::Lane { at_ns: 6, stage: "work".into(), lane: 2, spawned: true },
            ControlEvent::BlockedSpan {
                at_ns: 7,
                label: "a.0 -> b.0".into(),
                end: BlockEnd::Write,
                dur_ns: 12345,
            },
            ControlEvent::RateConverged {
                at_ns: 8,
                stream: StreamId(0),
                end: QueueEnd::Head,
                mbps: 321.5,
            },
            ControlEvent::Fault {
                at_ns: 9,
                target: "work".into(),
                lane: Some(3),
                restarts: 2,
                escalated: false,
                message: "index out of bounds".into(),
            },
            ControlEvent::Fault {
                at_ns: 10,
                target: "session".into(),
                lane: None,
                restarts: 0,
                escalated: true,
                message: "deadline exceeded".into(),
            },
            ControlEvent::StallSuspected { at_ns: 11, stage: "work".into(), epochs: 8 },
            ControlEvent::Shed { at_ns: 12, target: "source".into(), level: 3, shed_total: 4096 },
        ];
        for ev in evs {
            let line = ev.to_json().to_string();
            let back = Json::parse(&line).expect("round-trip");
            assert!(back.get("type").and_then(Json::as_str).is_some(), "{line}");
            assert_eq!(
                back.get("at_ns").and_then(Json::as_f64),
                Some(ev.at_ns() as f64),
                "{line}"
            );
        }
    }
}

/// Model-checks the transport half of the ring (not the full
/// [`EventRing`]): the producer writes a slot only when `tail - head`
/// (head read with Acquire) leaves room, Release-publishes `tail`, and
/// counts the event as dropped otherwise; consumers serialize on the
/// journal mutex, Acquire-load `tail`, take each published slot exactly
/// once, and Release-store `head` to re-own the slot to the producer.
/// The checked invariants are conservation (drained + dropped == emitted)
/// and publish-order delivery with no unpublished or double reads.
///
/// Off by default — same gating as the queue models: the dedicated CI
/// loom lane runs `RUSTFLAGS="--cfg loom" cargo test --features loom
/// --release --lib telemetry::ring`.
#[cfg(all(test, feature = "loom", loom))]
mod loom_model {
    use loom::cell::UnsafeCell;
    use loom::sync::atomic::{AtomicU64, Ordering};
    use loom::sync::{Arc, Mutex};

    const CAP: u64 = 2;

    struct Proto {
        tail: AtomicU64,
        head: AtomicU64,
        dropped: AtomicU64,
        slots: [UnsafeCell<u64>; CAP as usize],
        journal: Mutex<Vec<u64>>,
    }

    impl Proto {
        /// The consumer path of `EventRing::sync`: serialized by the
        /// journal mutex, Acquire on `tail`, Release on `head`.
        fn drain(&self) {
            let mut journal = self.journal.lock().unwrap();
            let tail = self.tail.load(Ordering::Acquire);
            let mut head = self.head.load(Ordering::Relaxed);
            while head != tail {
                let idx = (head % CAP) as usize;
                // SAFETY: slot `idx` is inside [head, tail) — published
                // by the tail Release store, exclusively ours under the
                // journal mutex.
                let v = self.slots[idx].with(|s| unsafe { *s });
                journal.push(v);
                head = head.wrapping_add(1);
                self.head.store(head, Ordering::Release);
            }
        }
    }

    #[test]
    fn emit_drain_overflow_conservation() {
        const EMITS: u64 = 3;
        loom::model(|| {
            let p = Arc::new(Proto {
                tail: AtomicU64::new(0),
                head: AtomicU64::new(0),
                dropped: AtomicU64::new(0),
                slots: [UnsafeCell::new(0), UnsafeCell::new(0)],
                journal: Mutex::new(Vec::new()),
            });

            // Producer: the control thread's `emit`.
            let q = p.clone();
            let prod = loom::thread::spawn(move || {
                for i in 0..EMITS {
                    let tail = q.tail.load(Ordering::Relaxed);
                    let head = q.head.load(Ordering::Acquire);
                    if tail.wrapping_sub(head) >= CAP {
                        q.dropped.fetch_add(1, Ordering::Relaxed);
                        continue;
                    }
                    let idx = (tail % CAP) as usize;
                    // SAFETY: slot `idx` is outside [head, tail) — the
                    // consumer re-owned it via the head Release store.
                    q.slots[idx].with_mut(|s| unsafe { *s = i + 1 });
                    q.tail.store(tail.wrapping_add(1), Ordering::Release);
                }
            });

            // A live exporter draining concurrently with the producer.
            let c = p.clone();
            let cons = loom::thread::spawn(move || c.drain());

            prod.join().unwrap();
            cons.join().unwrap();
            // End-of-run: the report builder's final drain.
            p.drain();

            let journal = p.journal.lock().unwrap();
            let dropped = p.dropped.load(Ordering::Relaxed);
            assert_eq!(
                journal.len() as u64 + dropped,
                EMITS,
                "conservation: drained + dropped != emitted"
            );
            // Publish-order delivery of exactly the accepted events: the
            // journal must be a strictly increasing subsequence of 1..=N
            // (a repeat would be a double read, a 0 an unpublished read).
            let mut prev = 0u64;
            for &v in journal.iter() {
                assert!(v > prev && v <= EMITS, "out-of-order or invalid value {v}");
                prev = v;
            }
        });
    }
}
