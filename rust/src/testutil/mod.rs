//! Lightweight property-based testing harness (proptest is not vendored),
//! plus shared test doubles.
//!
//! [`check`] runs a property over `cases` seeded random inputs; on the
//! first failure it performs bounded greedy shrinking via a user-supplied
//! shrinker and panics with the minimal counterexample. Deterministic:
//! the failing seed is printed so the case can be replayed.
//!
//! [`ScriptedStage`] is the scriptable [`ElasticStage`] double used by
//! controller-level integration tests and benches (threadless
//! `ElasticController::step` driving).
//!
//! [`ElasticController::step`]: crate::elastic::ElasticController::step

use std::sync::{Arc, Mutex};

use crate::elastic::{ElasticPolicy, ElasticStage};
use crate::queue::MonitorSample;
use crate::rng::Xoshiro256pp;

/// A scriptable [`ElasticStage`]: no threads, no queues — every active
/// lane reports a fixed per-probe service count (`tc_per_lane`) with no
/// blocked time, and `scale_to` applies the coordinated target verbatim
/// (policy-clamped). Lets tests and benches drive the controller's
/// decision loop deterministically.
pub struct ScriptedStage {
    name: &'static str,
    replicas: Mutex<usize>,
    policy: ElasticPolicy,
    tc_per_lane: u64,
}

impl ScriptedStage {
    pub fn new(
        name: &'static str,
        replicas: usize,
        policy: ElasticPolicy,
        tc_per_lane: u64,
    ) -> Arc<Self> {
        Arc::new(ScriptedStage { name, replicas: Mutex::new(replicas), policy, tc_per_lane })
    }
}

impl ElasticStage for ScriptedStage {
    fn stage_name(&self) -> &str {
        self.name
    }
    fn replicas(&self) -> usize {
        *self.replicas.lock().unwrap_or_else(|e| e.into_inner())
    }
    fn scale_to(&self, n: usize) -> usize {
        let n = self.policy.clamp(n);
        *self.replicas.lock().unwrap_or_else(|e| e.into_inner()) = n;
        n
    }
    fn lane_probe(&self) -> Vec<MonitorSample> {
        (0..self.replicas())
            .map(|_| MonitorSample {
                tc_head: self.tc_per_lane,
                tc_tail: self.tc_per_lane,
                read_blocked_ns: 0,
                write_blocked_ns: 0,
                ..Default::default()
            })
            .collect()
    }
    fn backlog(&self) -> usize {
        0
    }
    fn policy(&self) -> &ElasticPolicy {
        &self.policy
    }
    fn input_closed(&self) -> bool {
        false
    }
    fn join_workers(&self) {}
}

/// Property-check configuration.
#[derive(Debug, Clone, Copy)]
pub struct PropConfig {
    /// Random cases to run.
    pub cases: u32,
    /// Base seed (each case derives `seed + i`).
    pub seed: u64,
    /// Shrink attempts bound.
    pub max_shrink: u32,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 64, seed: 0x5EED, max_shrink: 400 }
    }
}

/// Run `prop` over `cases` inputs drawn by `gen`. `shrink` proposes smaller
/// candidates for a failing input (return an empty vec to stop).
pub fn check_with<T, G, S, P>(cfg: PropConfig, mut gen: G, shrink: S, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Xoshiro256pp) -> T,
    S: Fn(&T) -> Vec<T>,
    P: FnMut(&T) -> bool,
{
    for case in 0..cfg.cases {
        let seed = cfg.seed.wrapping_add(case as u64);
        let mut rng = Xoshiro256pp::new(seed);
        let input = gen(&mut rng);
        if prop(&input) {
            continue;
        }
        // Shrink greedily.
        let mut best = input;
        let mut budget = cfg.max_shrink;
        'outer: while budget > 0 {
            for cand in shrink(&best) {
                budget = budget.saturating_sub(1);
                if budget == 0 {
                    break 'outer;
                }
                if !prop(&cand) {
                    best = cand;
                    continue 'outer;
                }
            }
            break;
        }
        panic!(
            "property failed (seed {seed}, case {case}); minimal counterexample: {best:?}"
        );
    }
}

/// [`check_with`] without shrinking.
pub fn check<T, G, P>(cfg: PropConfig, gen: G, prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Xoshiro256pp) -> T,
    P: FnMut(&T) -> bool,
{
    check_with(cfg, gen, |_| Vec::new(), prop);
}

/// Common generator: a f64 vector with length in [lo_len, hi_len] and
/// values in [lo, hi].
pub fn gen_vec_f64(
    rng: &mut Xoshiro256pp,
    lo_len: usize,
    hi_len: usize,
    lo: f64,
    hi: f64,
) -> Vec<f64> {
    let len = lo_len + (rng.next_bounded((hi_len - lo_len + 1) as u32) as usize);
    (0..len).map(|_| rng.uniform(lo, hi)).collect()
}

/// Standard shrinker for vectors: halves, tail-trims, element simplification.
pub fn shrink_vec_f64(v: &[f64]) -> Vec<Vec<f64>> {
    let mut out = Vec::new();
    if v.len() > 1 {
        out.push(v[..v.len() / 2].to_vec());
        out.push(v[1..].to_vec());
        out.push(v[..v.len() - 1].to_vec());
    }
    // Round elements toward zero.
    if v.iter().any(|x| x.fract() != 0.0) {
        out.push(v.iter().map(|x| x.trunc()).collect());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_is_quiet() {
        check(
            PropConfig::default(),
            |rng| gen_vec_f64(rng, 0, 32, -10.0, 10.0),
            |v| v.len() <= 32,
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(
            PropConfig { cases: 16, ..Default::default() },
            |rng| rng.next_bounded(100),
            |&x| x < 50,
        );
    }

    #[test]
    fn shrinking_minimizes() {
        // Property: "no vector contains a value > 5". Failing inputs should
        // shrink toward short vectors still containing a > 5 value.
        let result = std::panic::catch_unwind(|| {
            check_with(
                PropConfig { cases: 32, seed: 1, max_shrink: 500 },
                |rng| gen_vec_f64(rng, 1, 64, 0.0, 10.0),
                |v| shrink_vec_f64(v),
                |v| v.iter().all(|&x| x <= 5.0),
            );
        });
        let msg = match result {
            Err(e) => *e.downcast::<String>().expect("panic payload"),
            Ok(()) => panic!("property should have failed"),
        };
        // The minimal counterexample should be very short.
        assert!(msg.contains("minimal counterexample"), "{msg}");
    }

    #[test]
    fn gen_vec_respects_bounds() {
        let mut rng = Xoshiro256pp::new(9);
        for _ in 0..100 {
            let v = gen_vec_f64(&mut rng, 2, 5, -1.0, 1.0);
            assert!(v.len() >= 2 && v.len() <= 5);
            assert!(v.iter().all(|x| (-1.0..1.0).contains(x)));
        }
    }
}
