//! Stable, monotonic, low-latency time reference (paper §IV-A, ref. [2]).
//!
//! The paper requires "a stable time reference across all utilized cores"
//! whose back-to-back latency is ~50–300 ns. On x86_64 we read the TSC
//! (`rdtsc`; invariant on every post-2010 part) and calibrate cycles→ns
//! against `CLOCK_MONOTONIC`; elsewhere we fall back to `clock_gettime`
//! directly, which on modern Linux is a vDSO call in the same latency class.
//!
//! [`TimeRef::min_latency_ns`] reproduces the paper's "minimum latency of
//! back-to-back timing requests" probe that seeds the sampling-period
//! controller (Fig. 6).

use std::sync::OnceLock;
use std::time::Duration;

/// Nanoseconds since an arbitrary (per-process) epoch.
pub type Nanos = u64;

/// Calibrated cycles-per-nanosecond for the TSC path.
#[derive(Debug, Clone, Copy)]
struct Calibration {
    /// TSC ticks per nanosecond (≈ base clock GHz).
    ticks_per_ns: f64,
    /// TSC value at calibration start — subtracted so readings start small.
    tsc_epoch: u64,
}

static CALIBRATION: OnceLock<Option<Calibration>> = OnceLock::new();

#[inline]
fn raw_monotonic_ns() -> Nanos {
    let mut ts = libc::timespec { tv_sec: 0, tv_nsec: 0 };
    // SAFETY: plain libc call with a valid out-pointer.
    unsafe {
        libc::clock_gettime(libc::CLOCK_MONOTONIC, &mut ts);
    }
    (ts.tv_sec as u64) * 1_000_000_000 + ts.tv_nsec as u64
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn rdtsc() -> u64 {
    // SAFETY: `_rdtsc` has no preconditions.
    unsafe { core::arch::x86_64::_rdtsc() }
}

#[cfg(target_arch = "x86_64")]
fn calibrate() -> Option<Calibration> {
    // Measure TSC frequency against CLOCK_MONOTONIC over ~5 ms, twice,
    // keeping the run with the smaller wall-clock jitter.
    let mut best: Option<Calibration> = None;
    let mut best_err = f64::INFINITY;
    for _ in 0..2 {
        let w0 = raw_monotonic_ns();
        let t0 = rdtsc();
        std::thread::sleep(Duration::from_millis(5));
        let w1 = raw_monotonic_ns();
        let t1 = rdtsc();
        let dw = (w1 - w0) as f64;
        let dt = (t1.wrapping_sub(t0)) as f64;
        if dw <= 0.0 || dt <= 0.0 {
            continue;
        }
        let tpn = dt / dw;
        // Sanity: clock rates between 0.2 and 10 GHz.
        if !(0.2..=10.0).contains(&tpn) {
            continue;
        }
        // Jitter estimate: re-read and compare.
        let err = (raw_monotonic_ns() - w1) as f64;
        if err < best_err {
            best_err = err;
            best = Some(Calibration { ticks_per_ns: tpn, tsc_epoch: t0 });
        }
    }
    best
}

#[cfg(not(target_arch = "x86_64"))]
fn calibrate() -> Option<Calibration> {
    None
}

/// The process-wide time reference.
///
/// All threads share one calibration so readings are comparable across
/// cores (the paper's prerequisite for the monitor thread observing
/// producer/consumer threads on other cores).
#[derive(Debug, Clone, Copy, Default)]
pub struct TimeRef;

impl TimeRef {
    /// Create (and lazily calibrate) the time reference.
    pub fn new() -> Self {
        let _ = CALIBRATION.get_or_init(calibrate);
        TimeRef
    }

    /// Current time in nanoseconds since the per-process epoch.
    #[inline]
    pub fn now_ns(&self) -> Nanos {
        match CALIBRATION.get_or_init(calibrate) {
            #[cfg(target_arch = "x86_64")]
            Some(c) => {
                let dt = rdtsc().wrapping_sub(c.tsc_epoch);
                (dt as f64 / c.ticks_per_ns) as Nanos
            }
            #[cfg(not(target_arch = "x86_64"))]
            Some(_) => raw_monotonic_ns(),
            None => raw_monotonic_ns(),
        }
    }

    /// True if the fast TSC path is active (vs the `clock_gettime` fallback).
    pub fn is_tsc(&self) -> bool {
        CALIBRATION.get_or_init(calibrate).is_some()
    }

    /// The paper's probe: minimum observed latency of back-to-back reads,
    /// in nanoseconds. This seeds the sampling-period controller and the
    /// Fig. 6 reproduction.
    pub fn min_latency_ns(&self) -> Nanos {
        let mut min = u64::MAX;
        for _ in 0..4096 {
            let a = self.now_ns();
            let b = self.now_ns();
            let d = b.saturating_sub(a);
            if d > 0 && d < min {
                min = d;
            }
        }
        if min == u64::MAX {
            // Sub-ns resolution readings: call it 1 ns.
            1
        } else {
            min
        }
    }

    /// Busy-wait until `deadline_ns`; returns the overshoot in ns.
    ///
    /// Used by the workload kernels to burn a precise service time and by
    /// the monitor to realize its sampling period without sleeping past it
    /// (OS sleep granularity is far coarser than µs-level `T`).
    #[inline]
    pub fn spin_until(&self, deadline_ns: Nanos) -> Nanos {
        loop {
            let now = self.now_ns();
            if now >= deadline_ns {
                return now - deadline_ns;
            }
            // Hint the CPU we are spinning; keeps SMT siblings usable.
            std::hint::spin_loop();
        }
    }

    /// Hybrid wait: OS-sleep the bulk, spin the final stretch. Returns the
    /// realized wait in ns. Monitors use this so a ms-scale `T` does not
    /// burn a core, while µs-scale `T` stays precise.
    pub fn wait_until(&self, deadline_ns: Nanos) -> Nanos {
        self.wait_until_with_tail(deadline_ns, 60_000)
    }

    /// [`wait_until`](Self::wait_until) with an explicit spin-tail budget.
    ///
    /// §Perf: the spin tail is pure CPU burn; on oversubscribed hosts a
    /// fixed 60 µs tail at a 400 µs period steals ~15% of a core from the
    /// application (measured in benches/overhead.rs). The monitor passes
    /// `T/16` clamped to [5 µs, 60 µs] — sleep overshoot past the deadline
    /// then shows up as a realized-period overrun, which the §IV-A
    /// controller absorbs by widening T. Self-correcting by construction.
    pub fn wait_until_with_tail(&self, deadline_ns: Nanos, spin_tail_ns: u64) -> Nanos {
        let start = self.now_ns();
        if deadline_ns > start + spin_tail_ns {
            let sleep_ns = deadline_ns - start - spin_tail_ns;
            std::thread::sleep(Duration::from_nanos(sleep_ns));
        }
        self.spin_until(deadline_ns);
        self.now_ns() - start
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotonic() {
        let t = TimeRef::new();
        let mut prev = t.now_ns();
        for _ in 0..10_000 {
            let now = t.now_ns();
            assert!(now >= prev, "time went backwards: {now} < {prev}");
            prev = now;
        }
    }

    #[test]
    fn tracks_wall_clock() {
        let t = TimeRef::new();
        let a = t.now_ns();
        std::thread::sleep(Duration::from_millis(20));
        let b = t.now_ns();
        let dt = (b - a) as f64;
        // Within 25% of the requested 20 ms (sleep can overshoot).
        assert!(dt > 15.0e6, "dt = {dt}");
        assert!(dt < 120.0e6, "dt = {dt}");
    }

    #[test]
    fn min_latency_reasonable() {
        let t = TimeRef::new();
        let lat = t.min_latency_ns();
        // Paper: ~50-300 ns on most systems; allow a wide envelope for CI.
        assert!(lat >= 1 && lat < 100_000, "latency = {lat}");
    }

    #[test]
    fn spin_until_hits_deadline() {
        let t = TimeRef::new();
        let start = t.now_ns();
        let overshoot = t.spin_until(start + 50_000);
        assert!(t.now_ns() >= start + 50_000);
        // Overshoot should be tiny relative to the 50 µs wait.
        assert!(overshoot < 50_000, "overshoot = {overshoot}");
    }

    #[test]
    fn cross_thread_comparable() {
        let t = TimeRef::new();
        let a = t.now_ns();
        let b = std::thread::spawn(move || TimeRef::new().now_ns())
            .join()
            .unwrap();
        let c = t.now_ns();
        // The other thread's reading falls inside [a, c] modulo latency.
        assert!(b + 1_000_000 >= a, "b={b} a={a}");
        assert!(b <= c + 1_000_000, "b={b} c={c}");
    }
}
