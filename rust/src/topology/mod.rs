//! Application graphs: kernels + streams — the **compiled low-level
//! form** underneath the [`crate::flow`] builder.
//!
//! The builder wires typed SPSC streams between kernel ports, validates
//! the graph (contiguous port indices, single producer/consumer per
//! stream), and hands everything to the [`crate::scheduler`]. Wiring is
//! **type-checked at compile time**: [`Topology::connect`] takes an
//! [`Outlet<T>`]/[`Inlet<T>`] pair whose item types must unify, so a
//! mismatched edge never reaches the runtime's `Any` downcasts. Kernel
//! duplication (the parallelization the paper's §I motivates) comes in two
//! forms: static fan-out wiring in the apps layer, and **declared
//! replicable stages** ([`Topology::add_elastic_stage`]) whose replica
//! count the [`crate::elastic`] control plane adjusts at run time.

use std::any::Any;
use std::collections::HashMap;
use std::sync::Arc;

use crate::elastic::{
    ElasticStage, ElasticStageConfig, MergeKernel, Replicable, ReplicaSet, SplitKernel,
};
use crate::flow::{Inlet, Outlet, StageIo};
use crate::kernel::Kernel;
use crate::port::{InputPort, OutputPort, PortCloser};
use crate::queue::{MonitorHandle, StreamConfig};
use crate::{Result, SfError};

/// Kernel identifier within a topology.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct KernelId(pub usize);

/// Stream identifier within a topology.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct StreamId(pub usize);

/// A kernel plus its (type-erased) port bundles, assembled by `connect`.
pub(crate) struct KernelNode {
    pub kernel: Box<dyn Kernel>,
    /// (port index, erased InputPort<T>)
    pub inputs: Vec<(usize, Box<dyn Any + Send>)>,
    /// (port index, erased OutputPort<T>, closer clone)
    pub outputs: Vec<(usize, Box<dyn Any + Send>, Box<dyn PortCloser>)>,
}

/// Stream metadata retained for monitoring and reports.
pub struct StreamEdge {
    pub id: StreamId,
    pub src: KernelId,
    pub src_port: usize,
    pub dst: KernelId,
    pub dst_port: usize,
    pub config: StreamConfig,
    pub monitor: Arc<dyn MonitorHandle>,
    /// "kernelA.port -> kernelB.port" label for reports.
    pub label: String,
}

/// A replicable stage registered with [`Topology::add_elastic_stage`]:
/// the type-erased replica manager plus its boundary kernels, for the
/// scheduler to hand to the elastic controller.
pub struct ElasticStageDecl {
    /// The run-time replica manager (shared with split/merge kernels).
    pub stage: Arc<dyn ElasticStage>,
    /// The stage's ingress kernel (its input stream carries λ).
    pub split: KernelId,
    /// The stage's egress kernel.
    pub merge: KernelId,
}

/// The application graph under construction.
pub struct Topology {
    name: String,
    pub(crate) kernels: Vec<KernelNode>,
    pub(crate) streams: Vec<StreamEdge>,
    pub(crate) elastic: Vec<ElasticStageDecl>,
    /// Transport accounting for network-backed edges (see [`crate::net`]);
    /// the scheduler exports these as `sf_net_*` gauges and folds their
    /// faults / in-flight losses into the run report.
    pub(crate) net_edges: Vec<Arc<crate::net::NetEdgeStats>>,
    kernel_names: Vec<String>,
    /// (kernel, port) -> stream, for duplicate-wiring detection.
    used_out: HashMap<(usize, usize), StreamId>,
    used_in: HashMap<(usize, usize), StreamId>,
}

impl Topology {
    pub fn new(name: impl Into<String>) -> Self {
        Topology {
            name: name.into(),
            kernels: Vec::new(),
            streams: Vec::new(),
            elastic: Vec::new(),
            net_edges: Vec::new(),
            kernel_names: Vec::new(),
            used_out: HashMap::new(),
            used_in: HashMap::new(),
        }
    }

    /// Application name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Add a kernel; returns its id.
    pub fn add_kernel(&mut self, kernel: Box<dyn Kernel>) -> KernelId {
        let id = KernelId(self.kernels.len());
        self.kernel_names.push(kernel.name().to_string());
        self.kernels.push(KernelNode { kernel, inputs: Vec::new(), outputs: Vec::new() });
        id
    }

    /// Kernel name lookup (reports).
    pub fn kernel_name(&self, id: KernelId) -> &str {
        &self.kernel_names[id.0]
    }

    /// Number of kernels.
    pub fn num_kernels(&self) -> usize {
        self.kernels.len()
    }

    /// Stream metadata.
    pub fn streams(&self) -> &[StreamEdge] {
        &self.streams
    }

    /// Mutable stream metadata ([`crate::flow::Session`] re-bases
    /// default-capacity edges through this before spawning).
    pub(crate) fn streams_mut(&mut self) -> &mut [StreamEdge] {
        &mut self.streams
    }

    /// Typed handle to output `port` of `k` (the type-claim site for
    /// mesh wiring; linear pipelines get handles from the
    /// [`crate::flow::Flow`] builder instead).
    pub fn outlet<T: Send + 'static>(&self, k: KernelId, port: usize) -> Outlet<T> {
        Outlet::new(k, port)
    }

    /// Typed handle to input `port` of `k`.
    pub fn inlet<T: Send + 'static>(&self, k: KernelId, port: usize) -> Inlet<T> {
        Inlet::new(k, port)
    }

    /// Registered replicable stages.
    pub fn elastic_stages(&self) -> &[ElasticStageDecl] {
        &self.elastic
    }

    /// Register the transport stats of a network-backed edge so the run
    /// exports its `sf_net_*` gauges and audits its faults and in-flight
    /// losses. Call once per [`crate::net::NetSink`]/[`crate::net::NetSource`]
    /// added to this topology, passing the same `Arc` the kernel holds.
    pub fn register_net_edge(&mut self, stats: Arc<crate::net::NetEdgeStats>) {
        self.net_edges.push(stats);
    }

    /// Transport stats registered with [`Topology::register_net_edge`].
    pub fn net_edges(&self) -> &[Arc<crate::net::NetEdgeStats>] {
        &self.net_edges
    }

    /// Declare a **replicable stage**: a `Split → {replica…} → Merge`
    /// block whose worker count the elastic control plane may change at
    /// run time (see [`crate::elastic`]).
    ///
    /// `factory` builds one replica body per worker (`replica_index` is
    /// handed in for seeding). Returns the stage's typed boundary
    /// ([`StageIo`]): wire the upstream stream into `io.inlet()` and the
    /// downstream stream out of `io.outlet()` — the handles carry the
    /// replica body's `In`/`Out` types, so the surrounding wiring is
    /// checked against the stage at compile time.
    pub fn add_elastic_stage<R, F>(
        &mut self,
        name: impl Into<String>,
        cfg: ElasticStageConfig,
        factory: F,
    ) -> Result<StageIo<R::In, R::Out>>
    where
        R: Replicable,
        F: Fn(usize) -> R + Send + Sync + 'static,
    {
        let set: Arc<ReplicaSet<R::In, R::Out>> = ReplicaSet::new(name, cfg, move |i| {
            Box::new(factory(i)) as Box<dyn Replicable<In = R::In, Out = R::Out>>
        })?;
        let split = self.add_kernel(Box::new(SplitKernel::new(set.clone())));
        let merge = self.add_kernel(Box::new(MergeKernel::new(set.clone())));
        self.elastic.push(ElasticStageDecl { stage: set, split, merge });
        Ok(StageIo::new(split, merge))
    }

    /// Wire a typed edge: both handles must carry the **same** item type
    /// `T`, so a producer/consumer type mismatch is a compile error (see
    /// the `compile_fail` examples in [`crate::flow`]).
    pub fn connect<T: Send + 'static>(
        &mut self,
        from: Outlet<T>,
        to: Inlet<T>,
        cfg: StreamConfig,
    ) -> Result<StreamId> {
        self.connect_indexed::<T>(from.kernel(), from.port(), to.kernel(), to.port(), cfg)
    }

    /// Raw index-pair wiring: `src.src_port -> dst.dst_port` with item
    /// type `T`. Low-level — the typed [`Topology::connect`] and the
    /// [`crate::flow`] builder are the public surfaces; this survives
    /// for their internals.
    pub fn connect_indexed<T: Send + 'static>(
        &mut self,
        src: KernelId,
        src_port: usize,
        dst: KernelId,
        dst_port: usize,
        cfg: StreamConfig,
    ) -> Result<StreamId> {
        if src.0 >= self.kernels.len() {
            return Err(SfError::Topology(format!("unknown src kernel {src:?}")));
        }
        if dst.0 >= self.kernels.len() {
            return Err(SfError::Topology(format!("unknown dst kernel {dst:?}")));
        }
        if let Some(s) = self.used_out.get(&(src.0, src_port)) {
            return Err(SfError::Topology(format!(
                "output port {src_port} of {} already wired to stream {s:?}",
                self.kernel_name(src)
            )));
        }
        if let Some(s) = self.used_in.get(&(dst.0, dst_port)) {
            return Err(SfError::Topology(format!(
                "input port {dst_port} of {} already wired to stream {s:?}",
                self.kernel_name(dst)
            )));
        }
        let id = StreamId(self.streams.len());
        let (q, monitor) = crate::queue::build::<T>(&cfg);
        let label = format!(
            "{}.{} -> {}.{}",
            self.kernel_name(src),
            src_port,
            self.kernel_name(dst),
            dst_port
        );
        let out = OutputPort::new(q.clone());
        let closer: Box<dyn PortCloser> = Box::new(OutputPort::new(q.clone()));
        self.kernels[src.0].outputs.push((src_port, Box::new(out), closer));
        self.kernels[dst.0].inputs.push((dst_port, Box::new(InputPort::new(q))));
        self.used_out.insert((src.0, src_port), id);
        self.used_in.insert((dst.0, dst_port), id);
        self.streams.push(StreamEdge {
            id,
            src,
            src_port,
            dst,
            dst_port,
            config: cfg,
            monitor,
            label,
        });
        Ok(id)
    }

    /// Validate the assembled graph: port indices per kernel must be
    /// contiguous from 0 (so `ctx.input(i)` indexing is meaningful).
    pub fn validate(&self) -> Result<()> {
        for (kid, node) in self.kernels.iter().enumerate() {
            let mut ins: Vec<usize> = node.inputs.iter().map(|(i, _)| *i).collect();
            ins.sort_unstable();
            for (expect, got) in ins.iter().enumerate() {
                if expect != *got {
                    return Err(SfError::Topology(format!(
                        "kernel {} input ports not contiguous: expected {expect}, found {got}",
                        self.kernel_names[kid]
                    )));
                }
            }
            let mut outs: Vec<usize> = node.outputs.iter().map(|(i, _, _)| *i).collect();
            outs.sort_unstable();
            for (expect, got) in outs.iter().enumerate() {
                if expect != *got {
                    return Err(SfError::Topology(format!(
                        "kernel {} output ports not contiguous: expected {expect}, found {got}",
                        self.kernel_names[kid]
                    )));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::{ClosureSink, ClosureSource};

    fn src() -> Box<dyn Kernel> {
        let mut n = 0u64;
        Box::new(ClosureSource::new("src", move || {
            n += 1;
            (n <= 10).then_some(n)
        }))
    }

    fn snk() -> Box<dyn Kernel> {
        Box::new(ClosureSink::new("snk", |_: u64| {}))
    }

    fn wire_u64(t: &mut Topology, a: KernelId, ap: usize, b: KernelId, bp: usize) -> Result<StreamId> {
        t.connect(Outlet::<u64>::new(a, ap), Inlet::<u64>::new(b, bp), StreamConfig::default())
    }

    #[test]
    fn builds_and_validates() {
        let mut t = Topology::new("t");
        let a = t.add_kernel(src());
        let b = t.add_kernel(snk());
        let s = wire_u64(&mut t, a, 0, b, 0).unwrap();
        assert_eq!(s, StreamId(0));
        assert_eq!(t.num_kernels(), 2);
        assert_eq!(t.streams().len(), 1);
        assert_eq!(t.streams()[0].label, "src.0 -> snk.0");
        t.validate().unwrap();
    }

    #[test]
    fn rejects_unknown_kernels() {
        let mut t = Topology::new("t");
        let a = t.add_kernel(src());
        assert!(wire_u64(&mut t, a, 0, KernelId(5), 0).is_err());
        assert!(wire_u64(&mut t, KernelId(5), 0, a, 0).is_err());
    }

    #[test]
    fn rejects_double_wiring() {
        let mut t = Topology::new("t");
        let a = t.add_kernel(src());
        let b = t.add_kernel(snk());
        let c = t.add_kernel(snk());
        wire_u64(&mut t, a, 0, b, 0).unwrap();
        assert!(wire_u64(&mut t, a, 0, c, 0).is_err());
    }

    #[test]
    fn elastic_stage_registers_split_and_merge() {
        use crate::elastic::{ElasticStageConfig, Replicable};
        struct Id;
        impl Replicable for Id {
            type In = u64;
            type Out = u64;
            fn process(&mut self, v: u64) -> u64 {
                v
            }
        }
        let mut t = Topology::new("e");
        let a = t.add_kernel(src());
        let stage = t.add_elastic_stage("st", ElasticStageConfig::default(), |_| Id).unwrap();
        let b = t.add_kernel(snk());
        // The stage's typed handles wire directly — no port indices, and
        // the u64 item type is inferred from `Replicable::{In, Out}`.
        t.connect(Outlet::new(a, 0), stage.inlet(), StreamConfig::default()).unwrap();
        t.connect(stage.outlet(), Inlet::new(b, 0), StreamConfig::default()).unwrap();
        t.validate().unwrap();
        assert_eq!(t.elastic_stages().len(), 1);
        assert_eq!(t.kernel_name(stage.split), "st-split");
        assert_eq!(t.kernel_name(stage.merge), "st-merge");
        assert_eq!(t.elastic_stages()[0].stage.replicas(), 1);
        // Dropping the (never-run) topology must join the replica workers
        // — covered by ReplicaSet's Drop.
    }

    #[test]
    fn rejects_gappy_ports() {
        let mut t = Topology::new("t");
        let a = t.add_kernel(src());
        let b = t.add_kernel(snk());
        // Wire output port 1 with port 0 missing.
        wire_u64(&mut t, a, 1, b, 0).unwrap();
        assert!(t.validate().is_err());
    }
}
