//! Deterministic fault-injection kernels for the supervision layer.
//!
//! Testing fault tolerance needs faults on demand: a panic at exactly
//! item *N*, a stall of exactly *d* milliseconds, a consumer slow enough
//! to pin the budget gate. These kernels inject each failure mode
//! deterministically so `tests/faults.rs` and `benches/faults.rs` can
//! assert the supervision invariants — restart-with-backoff, escalation,
//! poison propagation, watchdog flags, deadline aborts — and the
//! conservation equation (`delivered + lost + shed == offered`) exactly,
//! run after run.
//!
//! Two shapes per failure mode where it matters:
//!
//! * [`Replicable`] workers ([`PanicAtItem`], [`OpaquePanic`]) inject
//!   into **supervised lanes** — the panic lands in a replica worker,
//!   exercising restart budgets and lost-item audits.
//! * Plain [`Kernel`]s ([`PanicRelay`], [`StallRelay`], [`SlowConsumer`])
//!   inject into **unsupervised** pipeline threads — the panic/stall
//!   lands where only stream poisoning and the watchdog can contain it.

use std::time::Duration;

use super::Item;
use crate::elastic::Replicable;
use crate::kernel::{Kernel, KernelContext, KernelStatus};

/// Replicable pass-through worker that panics (with a string payload)
/// the first time it processes the item equal to `trip`.
#[derive(Debug, Clone)]
pub struct PanicAtItem {
    trip: Item,
}

impl PanicAtItem {
    pub fn new(trip: Item) -> Self {
        PanicAtItem { trip }
    }
}

impl Replicable for PanicAtItem {
    type In = Item;
    type Out = Item;

    fn process(&mut self, item: Item) -> Item {
        if item == self.trip {
            panic!("injected fault: panic at item {item}");
        }
        item
    }
}

/// Replicable worker that panics with a **non-string payload**
/// (`panic_any`) — exercises the opaque branch of
/// [`crate::error::panic_message`] end to end.
#[derive(Debug, Clone)]
pub struct OpaquePanic {
    trip: Item,
}

impl OpaquePanic {
    pub fn new(trip: Item) -> Self {
        OpaquePanic { trip }
    }
}

impl Replicable for OpaquePanic {
    type In = Item;
    type Out = Item;

    fn process(&mut self, item: Item) -> Item {
        if item == self.trip {
            std::panic::panic_any(item);
        }
        item
    }
}

/// Plain pass-through kernel that panics once it has relayed `trip`
/// items — a kernel-thread failure outside any supervised stage,
/// containable only by panic isolation + stream poisoning. The panic
/// fires *before* the next pop, so no item is ever consumed without
/// being produced: everything unrelayed strands in the poisoned input
/// queue, where the run report's stranded-item audit counts it.
pub struct PanicRelay {
    name: String,
    trip: u64,
    relayed: u64,
}

impl PanicRelay {
    /// Panic after exactly `trip` items have been relayed.
    pub fn new(name: impl Into<String>, trip: u64) -> Self {
        PanicRelay { name: name.into(), trip, relayed: 0 }
    }
}

impl Kernel for PanicRelay {
    fn name(&self) -> &str {
        &self.name
    }

    fn run(&mut self, ctx: &mut KernelContext) -> KernelStatus {
        if self.relayed == self.trip {
            panic!("injected fault: relay panic after {} items", self.relayed);
        }
        let inp = ctx.input::<Item>(0).expect("relay needs input port 0");
        match inp.pop() {
            None => KernelStatus::Done,
            Some(v) => {
                self.relayed += 1;
                if ctx.output::<Item>(0).expect("relay output").push(v).is_err() {
                    return KernelStatus::Done;
                }
                KernelStatus::Continue
            }
        }
    }
}

/// Pass-through kernel that stalls **once** — sleeps for `stall` when it
/// pops the item equal to `at`, then resumes relaying. While it sleeps,
/// neither of its queues moves, which is exactly the zero-progress
/// signature the controller's stall watchdog flags.
pub struct StallRelay {
    name: String,
    at: Item,
    stall: Duration,
    stalled: bool,
}

impl StallRelay {
    pub fn new(name: impl Into<String>, at: Item, stall: Duration) -> Self {
        StallRelay { name: name.into(), at, stall, stalled: false }
    }
}

impl Kernel for StallRelay {
    fn name(&self) -> &str {
        &self.name
    }

    fn run(&mut self, ctx: &mut KernelContext) -> KernelStatus {
        let inp = ctx.input::<Item>(0).expect("relay needs input port 0");
        match inp.pop() {
            None => KernelStatus::Done,
            Some(v) => {
                if v == self.at && !self.stalled {
                    self.stalled = true;
                    std::thread::sleep(self.stall);
                }
                if ctx.output::<Item>(0).expect("relay output").push(v).is_err() {
                    return KernelStatus::Done;
                }
                KernelStatus::Continue
            }
        }
    }
}

/// Sink that sleeps `per_item` after every pop — sustained backpressure
/// on demand, for driving the budget gate (and from there load shedding)
/// or for holding a deadline-bounded run past its deadline.
pub struct SlowConsumer {
    name: String,
    per_item: Duration,
    received: u64,
}

impl SlowConsumer {
    pub fn new(name: impl Into<String>, per_item: Duration) -> Self {
        SlowConsumer { name: name.into(), per_item, received: 0 }
    }

    pub fn received(&self) -> u64 {
        self.received
    }
}

impl Kernel for SlowConsumer {
    fn name(&self) -> &str {
        &self.name
    }

    fn run(&mut self, ctx: &mut KernelContext) -> KernelStatus {
        let inp = ctx.input::<Item>(0).expect("consumer needs input port 0");
        match inp.pop() {
            None => KernelStatus::Done,
            Some(_) => {
                self.received += 1;
                std::thread::sleep(self.per_item);
                KernelStatus::Continue
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn panic_workers_trip_exactly_once_at_the_trip_item() {
        let mut w = PanicAtItem::new(3);
        assert_eq!(w.process(2), 2);
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| w.process(3)))
            .expect_err("must panic at the trip item");
        assert_eq!(
            crate::error::panic_message(err.as_ref()),
            "injected fault: panic at item 3"
        );
        assert_eq!(w.process(4), 4, "non-trip items still pass");

        let mut o = OpaquePanic::new(1);
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| o.process(1)))
            .expect_err("must panic at the trip item");
        assert_eq!(
            crate::error::panic_message(err.as_ref()),
            "opaque panic payload",
            "panic_any payloads are reported opaquely, not lost"
        );
    }

    #[test]
    fn stall_relay_stalls_once_then_delivers_everything() {
        use crate::flow::{Flow, RunOptions, Session};
        use crate::kernel::{ClosureSink, ClosureSource};
        use std::sync::atomic::{AtomicU64, Ordering};
        use std::sync::Arc;
        let mut i = 0u64;
        let n = 100u64;
        let delivered = Arc::new(AtomicU64::new(0));
        let d2 = delivered.clone();
        let flow = Flow::new("stall")
            .source::<Item>(Box::new(ClosureSource::new("src", move || {
                i += 1;
                (i <= n).then_some(i - 1)
            })))
            .then::<Item>(Box::new(StallRelay::new(
                "stall",
                10,
                Duration::from_millis(30),
            )))
            .unwrap()
            .sink(Box::new(ClosureSink::new("snk", move |_: Item| {
                d2.fetch_add(1, Ordering::Relaxed);
            })))
            .unwrap();
        let report = Session::run_flow(flow, RunOptions::default()).unwrap();
        assert_eq!(delivered.load(Ordering::Relaxed), n, "a stall loses nothing");
        assert!(
            report.wall_ns >= 29_000_000,
            "the injected stall must show up in the wall clock"
        );
        assert!(report.faults.is_empty() && report.items_lost == 0);
    }
}
