//! Micro-benchmark workload kernels (paper §V-A).
//!
//! "A simple micro-benchmark consisting of two threads connected by a
//! lock-free queue is used. Each thread consists of a while loop that
//! consumes a fixed amount of time in order to simulate work with a known
//! service rate."
//!
//! [`RateControlledProducer`] burns a sampled service time then pushes one
//! 8-byte item; [`RateControlledConsumer`] pops one item then burns its
//! own service time. Dual-phase variants shift the distribution mean
//! halfway through (by items sent) for the Fig. 10/14/15 experiments.

pub mod faults;

use std::sync::Arc;

use crate::elastic::{ShedControl, Sheddable};
use crate::flow::Flow;
use crate::kernel::{Kernel, KernelContext, KernelStatus};
use crate::queue::StreamConfig;
use crate::rng::dist::{DistKind, Distribution};
use crate::rng::ServiceProcess;
use crate::timing::TimeRef;
use crate::topology::{StreamId, Topology};

/// The micro-benchmark item: 8 bytes, exactly as the paper's setup.
pub type Item = u64;
/// Bytes per item.
pub const ITEM_BYTES: usize = 8;

/// A service process + item description, buildable from the paper's
/// parameterization (rate in MB/s, distribution family).
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub process: ServiceProcess,
    pub item_bytes: usize,
}

impl WorkloadSpec {
    /// Deterministic service times at a fixed rate.
    pub fn fixed_rate_mbps(rate: f64) -> Self {
        WorkloadSpec {
            process: ServiceProcess::single(
                Distribution::from_rate_mbps(DistKind::Deterministic, rate, ITEM_BYTES),
                0x51D,
            ),
            item_bytes: ITEM_BYTES,
        }
    }

    /// Exponential service times with the given mean rate.
    pub fn exponential_mbps(rate: f64, seed: u64) -> Self {
        WorkloadSpec {
            process: ServiceProcess::single(
                Distribution::from_rate_mbps(DistKind::Exponential, rate, ITEM_BYTES),
                seed,
            ),
            item_bytes: ITEM_BYTES,
        }
    }

    /// General single-phase spec.
    pub fn single(kind: DistKind, rate_mbps: f64, seed: u64) -> Self {
        WorkloadSpec {
            process: ServiceProcess::single(
                Distribution::from_rate_mbps(kind, rate_mbps, ITEM_BYTES),
                seed,
            ),
            item_bytes: ITEM_BYTES,
        }
    }

    /// Dual-phase spec: `rate_a` until `switch_at` items, then `rate_b`
    /// (the paper's bi-modal environment-change simulation).
    pub fn dual_phase(
        kind: DistKind,
        rate_a_mbps: f64,
        rate_b_mbps: f64,
        switch_at: u64,
        seed: u64,
    ) -> Self {
        WorkloadSpec {
            process: ServiceProcess::dual(
                Distribution::from_rate_mbps(kind, rate_a_mbps, ITEM_BYTES),
                Distribution::from_rate_mbps(kind, rate_b_mbps, ITEM_BYTES),
                switch_at,
                seed,
            ),
            item_bytes: ITEM_BYTES,
        }
    }

    /// Mean rate (MB/s) of the currently-active phase.
    pub fn current_rate_mbps(&self) -> f64 {
        self.process.current().rate_mbps(self.item_bytes)
    }
}

/// The paper's Fig.-1 tandem topology, compiled: rate-controlled
/// producer → one stream → rate-controlled consumer. Built here **once**
/// (through the typed [`Flow`] builder) instead of being hand-wired by
/// every campaign, bench, example, and CLI path.
pub struct Tandem {
    /// The two-kernel graph, ready for
    /// [`Session::run`](crate::flow::Session::run).
    pub topology: Topology,
    /// The single producer→consumer stream (the monitored queue).
    pub stream: StreamId,
}

/// Build the Fig.-1 tandem: `producer` pushes `items` 8-byte items under
/// its service process, `consumer` pops under its own, across one stream
/// configured by `stream`.
pub fn tandem(
    name: impl Into<String>,
    producer: WorkloadSpec,
    consumer: WorkloadSpec,
    items: u64,
    stream: StreamConfig,
) -> crate::Result<Tandem> {
    let flow = Flow::new(name)
        .source::<Item>(Box::new(RateControlledProducer::new("producer", producer, items)))
        .sink_with(Box::new(RateControlledConsumer::new("consumer", consumer)), stream)?;
    let stream = flow.last_stream().expect("tandem wires exactly one stream");
    Ok(Tandem { topology: flow.finish(), stream })
}

/// The **no-catch-up deadline rule** shared by every paced kernel
/// ([`RateControlledProducer`], [`PacedProducer`], the Rabin–Karp
/// `PacedSegmenter`): the next deadline steps from the later of the
/// previous deadline and *now*. A while-loop server that was preempted
/// (or blocked) did not do work in the meantime, so the next item still
/// costs a full step from now — catch-up pacing would emit bursts after
/// a descheduling stall, precisely the "faster than the true service
/// rate" artifact Fig. 3 warns about, but as a systematic bias rather
/// than occasional noise.
#[derive(Debug, Default)]
pub struct Pacer {
    next_deadline_ns: Option<u64>,
}

impl Pacer {
    /// Advance the pacing state by `step_ns` and return the absolute
    /// deadline to wait for.
    pub fn next_deadline(&mut self, now_ns: u64, step_ns: u64) -> u64 {
        let d = match self.next_deadline_ns {
            Some(d) => d.max(now_ns) + step_ns,
            None => now_ns + step_ns,
        };
        self.next_deadline_ns = Some(d);
        d
    }
}

/// Producer kernel: burns service time, pushes `total_items`, then Done.
pub struct RateControlledProducer {
    name: String,
    spec: WorkloadSpec,
    total_items: u64,
    sent: u64,
    time: TimeRef,
    /// Deadline-based pacing keeps the long-run rate exact even when
    /// individual sleeps overshoot.
    pacer: Pacer,
}

impl RateControlledProducer {
    pub fn new(name: impl Into<String>, spec: WorkloadSpec, total_items: u64) -> Self {
        RateControlledProducer {
            name: name.into(),
            spec,
            total_items,
            sent: 0,
            time: TimeRef::new(),
            pacer: Pacer::default(),
        }
    }

    /// Items pushed so far.
    pub fn sent(&self) -> u64 {
        self.sent
    }
}

impl Kernel for RateControlledProducer {
    fn name(&self) -> &str {
        &self.name
    }

    fn run(&mut self, ctx: &mut KernelContext) -> KernelStatus {
        if self.sent >= self.total_items {
            return KernelStatus::Done;
        }
        let service_ns = self.spec.process.next_service_ns();
        let deadline = self.pacer.next_deadline(self.time.now_ns(), service_ns as u64);
        self.time.spin_until(deadline);
        let out = ctx.output::<Item>(0).expect("producer needs output port 0");
        if out.push(self.sent).is_err() {
            return KernelStatus::Done;
        }
        self.sent += 1;
        KernelStatus::Continue
    }
}

/// Consumer kernel: pops one item then burns its service time; Done when
/// upstream closes.
pub struct RateControlledConsumer {
    name: String,
    spec: WorkloadSpec,
    received: u64,
    time: TimeRef,
}

impl RateControlledConsumer {
    pub fn new(name: impl Into<String>, spec: WorkloadSpec) -> Self {
        RateControlledConsumer { name: name.into(), spec, received: 0, time: TimeRef::new() }
    }

    pub fn received(&self) -> u64 {
        self.received
    }
}

impl Kernel for RateControlledConsumer {
    fn name(&self) -> &str {
        &self.name
    }

    fn run(&mut self, ctx: &mut KernelContext) -> KernelStatus {
        let inp = ctx.input::<Item>(0).expect("consumer needs input port 0");
        match inp.pop() {
            None => KernelStatus::Done,
            Some(_) => {
                self.received += 1;
                // Burn a full service time from now (see the producer's
                // no-catch-up note): a preempted server does no work.
                let service_ns = self.spec.process.next_service_ns() as u64;
                let t = self.time.now_ns();
                self.time.spin_until(t + service_ns);
                KernelStatus::Continue
            }
        }
    }
}

/// Pass-through kernel with its own service time — builds longer chains.
pub struct RateControlledRelay {
    name: String,
    spec: WorkloadSpec,
    time: TimeRef,
}

impl RateControlledRelay {
    pub fn new(name: impl Into<String>, spec: WorkloadSpec) -> Self {
        RateControlledRelay { name: name.into(), spec, time: TimeRef::new() }
    }
}

impl Kernel for RateControlledRelay {
    fn name(&self) -> &str {
        &self.name
    }

    fn run(&mut self, ctx: &mut KernelContext) -> KernelStatus {
        let inp = ctx.input::<Item>(0).expect("relay needs input port 0");
        match inp.pop() {
            None => KernelStatus::Done,
            Some(v) => {
                let service_ns = self.spec.process.next_service_ns() as u64;
                let t = self.time.now_ns();
                self.time.spin_until(t + service_ns);
                if ctx.output::<Item>(0).expect("relay output").push(v).is_err() {
                    return KernelStatus::Done;
                }
                KernelStatus::Continue
            }
        }
    }
}

/// A producer paced by **hybrid sleep** instead of a pure spin: frees the
/// core between items so the elastic workloads (which run many threads on
/// few cores) measure stage behavior, not pacing-thread contention. The
/// long-run rate stays exact via the same no-catch-up deadline pacing as
/// [`RateControlledProducer`].
pub struct PacedProducer {
    name: String,
    interval_ns: u64,
    total_items: u64,
    /// Items emitted per wakeup (batched publish; 1 = item-at-a-time).
    burst: u64,
    sent: u64,
    time: TimeRef,
    pacer: Pacer,
    /// Degradation knob (see [`PacedProducer::with_shedding`]).
    shed: Option<Arc<ShedControl>>,
}

impl PacedProducer {
    /// Emit `total_items` at `rate` items/sec.
    pub fn from_rate_items_per_sec(
        name: impl Into<String>,
        rate: f64,
        total_items: u64,
    ) -> Self {
        assert!(rate > 0.0, "rate must be positive");
        PacedProducer {
            name: name.into(),
            interval_ns: (1.0e9 / rate).round().max(1.0) as u64,
            total_items,
            burst: 1,
            sent: 0,
            time: TimeRef::new(),
            pacer: Pacer::default(),
            shed: None,
        }
    }

    /// Emit in bursts of `n` items every `n` intervals: the long-run rate
    /// is unchanged, but each wakeup moves the whole burst with a single
    /// batched publish (`push_iter`) — one cross-core store per burst.
    pub fn with_burst(mut self, n: u64) -> Self {
        self.burst = n.max(1);
        self
    }

    /// Attach an awstream-style degradation knob: each burst, the
    /// current [`ShedControl::level`] decides how many of the burst's
    /// items are deliberately dropped (tail of the burst, audited via
    /// [`ShedControl::record_shed`]) instead of published. Register the
    /// same control with
    /// [`RunOptions::with_shedder`](crate::flow::RunOptions::with_shedder)
    /// and the elastic controller moves the level at run time.
    /// Conservation holds exactly: `delivered + shed == offered`.
    ///
    /// Note the per-burst floor: level `l` sheds
    /// `⌊burst · l / (SHED_LEVEL_MAX+1)⌋`, so shedding needs
    /// `burst > SHED_LEVEL_MAX / l` to bite (use [`with_burst`] ≥ 5).
    ///
    /// [`with_burst`]: PacedProducer::with_burst
    pub fn with_shedding(mut self, control: Arc<ShedControl>) -> Self {
        self.shed = Some(control);
        self
    }

    /// Items pushed so far.
    pub fn sent(&self) -> u64 {
        self.sent
    }
}

impl Sheddable for PacedProducer {
    /// The control installed by [`PacedProducer::with_shedding`].
    ///
    /// # Panics
    /// If the producer was built without one.
    fn shed_control(&self) -> Arc<ShedControl> {
        self.shed.clone().expect("PacedProducer built without with_shedding")
    }
}

impl Kernel for PacedProducer {
    fn name(&self) -> &str {
        &self.name
    }

    fn run(&mut self, ctx: &mut KernelContext) -> KernelStatus {
        if self.sent >= self.total_items {
            return KernelStatus::Done;
        }
        let step = self.interval_ns.saturating_mul(self.burst);
        let deadline = self.pacer.next_deadline(self.time.now_ns(), step);
        self.time.wait_until_with_tail(deadline, 20_000);
        let out = ctx.output::<Item>(0).expect("producer needs output port 0");
        let hi = (self.sent + self.burst).min(self.total_items);
        // Degradation: publish only the kept prefix of the burst; the
        // shed tail is skipped *and audited*, never silently dropped.
        // `quota(n) < n` for any level, so the burst always carries at
        // least one real item and `sent` always advances on success.
        let offered = hi - self.sent;
        let shed = self.shed.as_ref().map(|c| c.quota(offered)).unwrap_or(0);
        let keep_hi = hi - shed;
        match out.push_iter(self.sent..keep_hi) {
            Ok(n) => {
                self.sent += n as u64;
                if shed > 0 && self.sent == keep_hi {
                    // Kept prefix fully published — account the tail.
                    // (On a partial push the unsent remainder is simply
                    // re-offered, and re-quota'd, next wakeup.)
                    if let Some(c) = &self.shed {
                        c.record_shed(shed);
                    }
                    self.sent = hi;
                }
                KernelStatus::Continue
            }
            Err(_) => KernelStatus::Done,
        }
    }
}

/// The **parallelizable dual-phase** service stage for the elastic
/// experiments: burns a deterministic service time per item, shifting
/// from `fast` to `slow` at a wall-clock deadline.
///
/// Unlike [`WorkloadSpec::dual_phase`] (which switches after a
/// per-process item count), the phase here is keyed to the shared
/// [`TimeRef`] clock: replicas spawned by the control plane *after* the
/// shift must come up already in the slow phase, and replicas splitting
/// the item stream must not each wait for a private item count.
pub struct PhasedServiceWorker {
    fast_service_ns: u64,
    slow_service_ns: u64,
    switch_at_ns: u64,
    time: TimeRef,
}

impl PhasedServiceWorker {
    /// Service times in nanoseconds; `switch_at_ns` is an absolute
    /// [`TimeRef`] timestamp (e.g. `TimeRef::new().now_ns() + 2e9 as u64`).
    pub fn new(fast_service_ns: u64, slow_service_ns: u64, switch_at_ns: u64) -> Self {
        PhasedServiceWorker {
            fast_service_ns,
            slow_service_ns,
            switch_at_ns,
            time: TimeRef::new(),
        }
    }

    /// Paper-style parameterization: rates in MB/s over 8-byte items.
    pub fn from_rates_mbps(fast_mbps: f64, slow_mbps: f64, switch_at_ns: u64) -> Self {
        let ns = |mbps: f64| ((ITEM_BYTES as f64 / (mbps * 1.0e6)) * 1.0e9).round() as u64;
        PhasedServiceWorker::new(ns(fast_mbps), ns(slow_mbps), switch_at_ns)
    }

    /// The service time (ns) an item started *now* would cost.
    pub fn current_service_ns(&self) -> u64 {
        if self.time.now_ns() < self.switch_at_ns {
            self.fast_service_ns
        } else {
            self.slow_service_ns
        }
    }
}

impl crate::elastic::Replicable for PhasedServiceWorker {
    type In = Item;
    type Out = Item;

    fn process(&mut self, item: Item) -> Item {
        let service = self.current_service_ns();
        let t = self.time.now_ns();
        if service > 150_000 {
            // Long services sleep the bulk — replicas then overlap their
            // service times without needing a core each.
            self.time.wait_until_with_tail(t + service, 30_000);
        } else {
            self.time.spin_until(t + service);
        }
        item
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::{RunOptions, Session};

    #[test]
    fn pacer_never_catches_up() {
        let mut p = Pacer::default();
        assert_eq!(p.next_deadline(100, 10), 110);
        // On time: steps from the previous deadline (long-run rate exact).
        assert_eq!(p.next_deadline(105, 10), 120);
        // Stalled far past the deadline: steps from *now* — no burst.
        assert_eq!(p.next_deadline(500, 10), 510);
    }

    #[test]
    fn spec_rates() {
        let s = WorkloadSpec::fixed_rate_mbps(4.0);
        assert!((s.current_rate_mbps() - 4.0).abs() < 1e-9);
        let d = WorkloadSpec::dual_phase(DistKind::Deterministic, 2.0, 1.0, 100, 7);
        assert!((d.current_rate_mbps() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn producer_consumer_pipeline_realizes_rate() {
        // 8 MB/s producer into a fast consumer: wall time for N items
        // should match N · service_time within 30%.
        let rate = 8.0; // MB/s → 1 µs per 8-byte item
        let items = 50_000u64;
        let t = tandem(
            "wl",
            WorkloadSpec::fixed_rate_mbps(rate),
            WorkloadSpec::fixed_rate_mbps(100.0), // effectively unconstrained
            items,
            StreamConfig::default().with_capacity(4096),
        )
        .unwrap();
        let report = Session::run(t.topology, RunOptions::default()).unwrap();
        let expect_ns = items as f64 * 1000.0;
        let got = report.wall_ns as f64;
        // Loose bound: debug builds + parallel test load can stretch the
        // wall clock; the paced producer can never run *faster* though.
        assert!(got > 0.9 * expect_ns, "wall {got} ns impossibly fast (expected ≥ {expect_ns})");
        assert!(got < 3.0 * expect_ns, "wall {got} ns vs expected {expect_ns} ns");
        // The tandem exposes its single stream for rate lookups.
        let (pushes, pops) = report.stream_totals["producer.0 -> consumer.0"];
        assert_eq!((pushes, pops), (items, items));
        assert_eq!(t.stream.0, 0);
    }

    #[test]
    fn dual_phase_switches_at_item_count() {
        let mut spec = WorkloadSpec::dual_phase(DistKind::Deterministic, 8.0, 1.0, 10, 3);
        for _ in 0..10 {
            assert!((spec.process.next_service_ns() - 1000.0).abs() < 1e-9);
        }
        assert!((spec.process.next_service_ns() - 8000.0).abs() < 1e-9);
    }

    #[test]
    fn phased_worker_switches_on_the_shared_clock() {
        use crate::elastic::Replicable as _;
        let time = TimeRef::new();
        // Switch already in the past: the worker starts slow.
        let past = PhasedServiceWorker::new(1_000, 2_000, 0);
        assert_eq!(past.current_service_ns(), 2_000);
        // Switch far in the future: fast phase.
        let mut fut = PhasedServiceWorker::new(1_000, 2_000, time.now_ns() + 60_000_000_000);
        assert_eq!(fut.current_service_ns(), 1_000);
        assert_eq!(fut.process(7), 7);
        // MB/s parameterization: 8 MB/s over 8-byte items = 1 µs/item.
        let w = PhasedServiceWorker::from_rates_mbps(8.0, 2.0, 0);
        assert_eq!(w.fast_service_ns, 1_000);
        assert_eq!(w.slow_service_ns, 4_000);
    }

    #[test]
    fn paced_producer_realizes_rate_without_spinning() {
        let rate = 20_000.0; // items/sec → 50 µs interval
        let items = 2_000u64;
        let flow = Flow::new("paced")
            .stream_defaults(StreamConfig::default().with_capacity(4096))
            .source::<Item>(Box::new(PacedProducer::from_rate_items_per_sec(
                "paced", rate, items,
            )))
            .sink(Box::new(ClosureSinkCounter::default()))
            .unwrap();
        let t0 = TimeRef::new().now_ns();
        Session::run_flow(flow, RunOptions::default()).unwrap();
        let dt = (TimeRef::new().now_ns() - t0) as f64 / 1.0e9;
        let expect = items as f64 / rate;
        assert!(dt > 0.9 * expect, "{dt}s impossibly fast (expected ≥ {expect}s)");
        assert!(dt < 6.0 * expect, "{dt}s vs expected {expect}s");
    }

    #[test]
    fn paced_producer_burst_delivers_everything_batched() {
        use std::sync::atomic::{AtomicU64, Ordering};
        use std::sync::Arc;
        let rate = 500_000.0; // 2 µs interval → 128 µs per 64-item burst
        let items = 20_000u64;
        let delivered = Arc::new(AtomicU64::new(0));
        let d2 = delivered.clone();
        let flow = Flow::new("burst")
            .stream_defaults(StreamConfig::default().with_capacity(4096))
            .source::<Item>(Box::new(
                PacedProducer::from_rate_items_per_sec("burst", rate, items).with_burst(64),
            ))
            .sink(Box::new(crate::kernel::ClosureSink::new("cnt", move |_: Item| {
                d2.fetch_add(1, Ordering::Relaxed);
            })))
            .unwrap();
        Session::run_flow(flow, RunOptions::default()).unwrap();
        assert_eq!(delivered.load(Ordering::Relaxed), items, "burst lost items");
    }

    #[test]
    fn shedding_producer_conserves_offered_items() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let ctl = ShedControl::new();
        ctl.set_level(2); // shed 2/5 of every burst
        let items = 1_000u64;
        let delivered = Arc::new(AtomicU64::new(0));
        let d2 = delivered.clone();
        let flow = Flow::new("shed")
            .stream_defaults(StreamConfig::default().with_capacity(4096))
            .source::<Item>(Box::new(
                PacedProducer::from_rate_items_per_sec("shed", 1_000_000.0, items)
                    .with_burst(10)
                    .with_shedding(ctl.clone()),
            ))
            .sink(Box::new(crate::kernel::ClosureSink::new("cnt", move |_: Item| {
                d2.fetch_add(1, Ordering::Relaxed);
            })))
            .unwrap();
        Session::run_flow(flow, RunOptions::default()).unwrap();
        let got = delivered.load(Ordering::Relaxed);
        let shed = ctl.shed_total();
        assert!(shed > 0, "level 2 over 10-item bursts must shed");
        assert_eq!(got + shed, items, "delivered + shed must equal offered");
        // Level 2 sheds exactly ⌊10·2/5⌋ = 4 of every full burst.
        assert_eq!(shed, items / 10 * 4);
    }

    /// Minimal counting sink for the pacing test.
    #[derive(Default)]
    struct ClosureSinkCounter {
        n: u64,
    }
    impl Kernel for ClosureSinkCounter {
        fn name(&self) -> &str {
            "count"
        }
        fn run(&mut self, ctx: &mut KernelContext) -> KernelStatus {
            match ctx.input::<Item>(0).unwrap().pop() {
                Some(_) => {
                    self.n += 1;
                    KernelStatus::Continue
                }
                None => KernelStatus::Done,
            }
        }
    }
}
