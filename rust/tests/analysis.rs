//! Integration tests for the pre-run graph analyzer: random clean DAGs
//! pass, and a seeded defect per rule (A1–A5) is rejected with that
//! rule's stable id and kernel/stream provenance. Both shipped app
//! wirings must verify clean in every deployment shape.

use streamflow::analysis::{Rule, Severity, A5_MIN_CAPACITY};
use streamflow::apps::{matmul, rabin_karp};
use streamflow::config::{MatmulConfig, RabinKarpConfig};
use streamflow::elastic::ElasticConfig;
use streamflow::prelude::*;
use streamflow::rng::Xoshiro256pp;
use streamflow::testutil::{check, PropConfig};

/// Inert kernel for graph-shape tests (analysis never runs kernels).
struct Stub(String);

impl Kernel for Stub {
    fn name(&self) -> &str {
        &self.0
    }
    fn run(&mut self, _ctx: &mut KernelContext) -> KernelStatus {
        KernelStatus::Done
    }
}

fn stub(name: impl Into<String>) -> Box<dyn Kernel> {
    Box::new(Stub(name.into()))
}

/// A random DAG that is clean by construction: node 0 is the unique
/// source, every later node takes at least one edge from an earlier node
/// (so everything is reachable), and all edges point forward (so there
/// is no cycle). Extra forward edges are sprinkled at random.
#[derive(Debug, Clone)]
struct DagSpec {
    nodes: usize,
    /// (src, dst) with src < dst; includes the spanning edges.
    edges: Vec<(usize, usize)>,
}

fn gen_dag(rng: &mut Xoshiro256pp) -> DagSpec {
    let nodes = 2 + rng.next_bounded(7) as usize; // 2..=8
    let mut edges = Vec::new();
    for dst in 1..nodes {
        let src = rng.next_bounded(dst as u32) as usize;
        edges.push((src, dst));
    }
    let extras = rng.next_bounded(2 * nodes as u32) as usize;
    for _ in 0..extras {
        let src = rng.next_bounded(nodes as u32 - 1) as usize;
        let dst = src + 1 + rng.next_bounded((nodes - src - 1) as u32) as usize;
        edges.push((src, dst));
    }
    DagSpec { nodes, edges }
}

/// Build the spec as a topology; each wire claims the next free port on
/// both ends so ports stay contiguous.
fn build_dag(spec: &DagSpec) -> Topology {
    let mut t = Topology::new("prop-dag");
    let ids: Vec<KernelId> = (0..spec.nodes).map(|i| t.add_kernel(stub(format!("k{i}")))).collect();
    let mut out_ports = vec![0usize; spec.nodes];
    let mut in_ports = vec![0usize; spec.nodes];
    for &(src, dst) in &spec.edges {
        let op = out_ports[src];
        let ip = in_ports[dst];
        out_ports[src] += 1;
        in_ports[dst] += 1;
        t.connect(
            Outlet::<u64>::new(ids[src], op),
            Inlet::<u64>::new(ids[dst], ip),
            StreamConfig::default(),
        )
        .unwrap();
    }
    t
}

#[test]
fn random_clean_dags_pass() {
    check(
        PropConfig { cases: 48, ..Default::default() },
        gen_dag,
        |spec| {
            let t = build_dag(spec);
            let r = GraphAnalyzer::new().analyze(&t, &AnalysisContext::new());
            r.is_clean()
        },
    );
}

#[test]
fn random_dag_with_a_back_edge_is_rejected_as_a1() {
    // Seeded defect: close any clean DAG into a cycle by wiring its last
    // node back to node 0. The analyzer must flag A1 (the cycle) — and
    // since node 0 is then no longer a source, rules may stack, but the
    // cycle id itself must always be present with its provenance.
    check(
        PropConfig { cases: 24, ..Default::default() },
        gen_dag,
        |spec| {
            let mut t = build_dag(spec);
            let last = KernelId(spec.nodes - 1);
            let op = spec.edges.iter().filter(|&&(s, _)| s == spec.nodes - 1).count();
            let ip = spec.edges.iter().filter(|&&(_, d)| d == 0).count();
            t.connect(
                Outlet::<u64>::new(last, op),
                Inlet::<u64>::new(KernelId(0), ip),
                StreamConfig::default(),
            )
            .unwrap();
            let r = GraphAnalyzer::new().analyze(&t, &AnalysisContext::new());
            let Some(d) = r.errors().find(|d| d.rule == Rule::A1) else {
                return false;
            };
            d.rule.id() == "A1" && !d.kernels.is_empty() && !d.streams.is_empty()
        },
    );
}

#[test]
fn a1_two_kernel_cycle_reports_both_edges() {
    let mut t = Topology::new("looped");
    let a = t.add_kernel(stub("a"));
    let b = t.add_kernel(stub("b"));
    t.connect(Outlet::<u64>::new(a, 0), Inlet::new(b, 0), StreamConfig::default()).unwrap();
    t.connect(Outlet::<u64>::new(b, 0), Inlet::new(a, 0), StreamConfig::default()).unwrap();
    let r = GraphAnalyzer::new().analyze(&t, &AnalysisContext::new());
    let d = r.errors().find(|d| d.rule == Rule::A1).expect("A1 fires");
    assert_eq!(d.rule.id(), "A1");
    assert_eq!(d.severity, Severity::Error);
    assert_eq!(d.kernels.len(), 2, "both members in provenance: {}", r.render());
    assert_eq!(d.streams.len(), 2, "both edges in provenance: {}", r.render());
    assert!(r.render().contains("error[A1]"), "{}", r.render());
}

#[test]
fn a2_island_and_starved_sink_report_their_kernels() {
    let mut t = Topology::new("dangling");
    let a = t.add_kernel(stub("src"));
    let b = t.add_kernel(stub("snk"));
    t.connect(Outlet::<u64>::new(a, 0), Inlet::new(b, 0), StreamConfig::default()).unwrap();
    let island = t.add_kernel(stub("island"));
    // A side cycle no source feeds: x <-> y, with a sink hanging off it.
    let x = t.add_kernel(stub("x"));
    let y = t.add_kernel(stub("y"));
    let dead = t.add_kernel(stub("dead-sink"));
    t.connect(Outlet::<u64>::new(x, 0), Inlet::new(y, 0), StreamConfig::default()).unwrap();
    t.connect(Outlet::<u64>::new(y, 0), Inlet::new(x, 0), StreamConfig::default()).unwrap();
    t.connect(Outlet::<u64>::new(y, 1), Inlet::new(dead, 0), StreamConfig::default()).unwrap();
    let r = GraphAnalyzer::new().analyze(&t, &AnalysisContext::new());
    let a2: Vec<_> = r.errors().filter(|d| d.rule == Rule::A2).collect();
    assert!(!a2.is_empty(), "{}", r.render());
    for d in &a2 {
        assert_eq!(d.rule.id(), "A2");
        assert!(!d.kernels.is_empty(), "A2 without kernel provenance: {}", r.render());
    }
    let named = |name: &str| {
        a2.iter().any(|d| d.kernels.iter().any(|(_, n)| n == name))
    };
    assert!(named("island"), "island flagged: {}", r.render());
    assert!(named("dead-sink"), "starved sink flagged: {}", r.render());
    assert_eq!(t.kernel_name(island), "island");
}

#[test]
fn a3_budget_below_replica_floor_is_rejected() {
    struct Id;
    impl Replicable for Id {
        type In = u64;
        type Out = u64;
        fn process(&mut self, v: u64) -> u64 {
            v
        }
    }
    let mut t = Topology::new("over-floored");
    let a = t.add_kernel(stub("src"));
    let cfg = ElasticStageConfig {
        policy: ElasticPolicy { min_replicas: 3, max_replicas: 8, ..Default::default() },
        ..Default::default()
    };
    let stage = t.add_elastic_stage("wide", cfg, |_| Id).unwrap();
    let b = t.add_kernel(stub("snk"));
    t.connect(Outlet::new(a, 0), stage.inlet(), StreamConfig::default()).unwrap();
    t.connect(stage.outlet(), Inlet::new(b, 0), StreamConfig::default()).unwrap();

    // Fixed(2) can never cover min_replicas = 3.
    let elastic = ElasticConfig { worker_budget: BudgetPolicy::Fixed(2), ..Default::default() };
    let ctx = AnalysisContext::new().with_elastic(&elastic);
    let r = GraphAnalyzer::new().analyze(&t, &ctx);
    let d = r.errors().find(|d| d.rule == Rule::A3).expect("A3 fires");
    assert_eq!(d.rule.id(), "A3");
    assert!(d.message.contains("min_replicas"), "{}", r.render());

    // A HostAware budget whose *floor* undershoots but whose ceiling
    // covers is only a warning (feasible on an idle host).
    let elastic = ElasticConfig {
        worker_budget: BudgetPolicy::HostAware { headroom: 0.25, floor: 1, ceil: 8 },
        ..Default::default()
    };
    let ctx = AnalysisContext::new().with_elastic(&elastic);
    let r = GraphAnalyzer::new().analyze(&t, &ctx);
    assert!(!r.has_errors(), "floor shortfall is a warning: {}", r.render());
    assert!(
        r.warnings().any(|d| d.rule == Rule::A3 && d.message.contains("floor")),
        "{}",
        r.render()
    );
}

#[test]
fn a4_defective_shard_plans_are_rejected_with_ids() {
    let t = Topology::new("sharded");
    let plan = vec![
        NetEdgePlan::of::<u64>("feed:0", 0xF00D, 8),
        NetEdgePlan::of::<u64>("feed:0", 0xF00D, 8), // duplicate edge id
        NetEdgePlan::of::<u64>("results:0", 0xBEEF, 8), // topology-id split
        NetEdgePlan::untyped("raw:0", 0xF00D, "NotWireType"),
    ];
    let ctx = AnalysisContext::new().with_net_plan(&plan);
    let r = GraphAnalyzer::new().analyze(&t, &ctx);
    let a4: Vec<_> = r.errors().filter(|d| d.rule == Rule::A4).collect();
    assert!(a4.iter().all(|d| d.rule.id() == "A4"));
    assert!(a4.iter().any(|d| d.message.contains("feed:0")), "{}", r.render());
    assert!(a4.iter().any(|d| d.message.contains("Hello handshake")), "{}", r.render());
    assert!(a4.iter().any(|d| d.message.contains("NotWireType")), "{}", r.render());
}

#[test]
fn a5_undersized_instrumented_edge_warns_with_stream_provenance() {
    let mut t = Topology::new("tight");
    let a = t.add_kernel(stub("burst-src"));
    let b = t.add_kernel(stub("snk"));
    t.connect(
        Outlet::<u64>::new(a, 0),
        Inlet::new(b, 0),
        StreamConfig::default().with_capacity(A5_MIN_CAPACITY - 1),
    )
    .unwrap();
    let r = GraphAnalyzer::new().analyze(&t, &AnalysisContext::new());
    assert!(!r.has_errors(), "A5 is a warning: {}", r.render());
    let d = r.warnings().find(|d| d.rule == Rule::A5).expect("A5 fires");
    assert_eq!(d.rule.id(), "A5");
    assert_eq!(d.streams.len(), 1, "stream provenance: {}", r.render());
    assert!(
        d.kernels.iter().any(|(_, n)| n == "burst-src"),
        "producer provenance: {}",
        r.render()
    );

    // Same wiring, silenced per edge: clean.
    let mut t = Topology::new("tight-ack");
    let a = t.add_kernel(stub("burst-src"));
    let b = t.add_kernel(stub("snk"));
    t.connect(
        Outlet::<u64>::new(a, 0),
        Inlet::new(b, 0),
        StreamConfig::default().with_capacity(A5_MIN_CAPACITY - 1).silence_analysis(),
    )
    .unwrap();
    let r = GraphAnalyzer::new().analyze(&t, &AnalysisContext::new());
    assert!(r.is_clean(), "{}", r.render());
}

// ---------------------------------------------------------------- apps --

fn small_matmul() -> MatmulConfig {
    MatmulConfig { n: 64, block_rows: 8, ..Default::default() }
}

fn small_rabin_karp() -> RabinKarpConfig {
    RabinKarpConfig { corpus_bytes: 64 << 10, segment_bytes: 8 << 10, ..Default::default() }
}

#[test]
fn matmul_wirings_verify_clean() {
    let opts = RunOptions::default();
    let elastic = matmul::verify_matmul(&small_matmul(), None, &opts).unwrap();
    assert!(elastic.is_clean(), "{}", elastic.render());

    let mut cfg = small_matmul();
    cfg.static_degree = Some(4);
    let fixed = matmul::verify_matmul(&cfg, None, &opts).unwrap();
    assert!(fixed.is_clean(), "{}", fixed.render());

    let sharded = matmul::verify_matmul(&small_matmul(), Some(2), &opts).unwrap();
    assert!(sharded.is_clean(), "{}", sharded.render());
}

#[test]
fn rabin_karp_wirings_verify_clean() {
    let opts = RunOptions::default();
    let elastic = rabin_karp::verify_rabin_karp(&small_rabin_karp(), None, &opts).unwrap();
    assert!(elastic.is_clean(), "{}", elastic.render());

    let sharded = rabin_karp::verify_rabin_karp(&small_rabin_karp(), Some(2), &opts).unwrap();
    assert!(sharded.is_clean(), "{}", sharded.render());
}

#[test]
fn degenerate_app_configs_are_config_errors_not_reports() {
    let opts = RunOptions::default();
    let mut cfg = small_matmul();
    cfg.n = 0;
    assert!(matmul::verify_matmul(&cfg, None, &opts).is_err());
    assert!(matmul::verify_matmul(&small_matmul(), Some(0), &opts).is_err());

    let mut cfg = small_rabin_karp();
    cfg.pattern = String::new();
    assert!(rabin_karp::verify_rabin_karp(&cfg, None, &opts).is_err());
    assert!(rabin_karp::verify_rabin_karp(&small_rabin_karp(), Some(0), &opts).is_err());
}
