//! The elastic applications end to end: the migrated matmul and
//! Rabin–Karp apps must produce outputs identical to their static
//! baselines across seeds/configs, and the coordinated control plane must
//! replicate the loaded stage of a coupled pipeline while refusing the
//! starvation-bound one.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use streamflow::apps::matmul::{matmul_ref, random_matrix, run_matmul};
use streamflow::apps::rabin_karp::{foobar_corpus, naive_matches, run_rabin_karp};
use streamflow::config::{MatmulConfig, RabinKarpConfig};
use streamflow::elastic::{ElasticAction, ElasticConfig, ElasticStageConfig};
use streamflow::kernel::ClosureSink;
use streamflow::prelude::*;
use streamflow::workload::{Item, PacedProducer, PhasedServiceWorker};

#[test]
fn elastic_matmul_is_bit_identical_to_static_across_seeds() {
    for seed in [0xA11CE, 7, 0xDEAD_BEEF] {
        let base = MatmulConfig {
            n: 96,
            dot_kernels: 3,
            block_rows: 8,
            seed,
            ..Default::default()
        };
        let elastic = run_matmul(&base, RunOptions::default()).unwrap();
        let mut fixed_cfg = base.clone();
        fixed_cfg.static_degree = Some(3);
        let fixed = run_matmul(&fixed_cfg, RunOptions::default()).unwrap();
        // Per-block compute is byte-for-byte the same code in both
        // wirings and blocks land in C by row index, so the products are
        // bit-identical — not merely close.
        assert_eq!(elastic.c, fixed.c, "seed {seed:#x}: elastic vs static C differ");
        let a = random_matrix(base.n, seed);
        let b = random_matrix(base.n, seed ^ 0xFEED);
        let expect = matmul_ref(&a, &b, base.n);
        for (i, (&got, &want)) in elastic.c.iter().zip(&expect).enumerate() {
            assert!((got - want).abs() < 1e-3, "seed {seed:#x} C[{i}]: {got} vs {want}");
        }
        // The control plane ran and recorded the dot stage's trajectory.
        assert_eq!(elastic.report.replica_trajectories.len(), 1);
        assert!(!elastic.report.replica_trajectories[0].points.is_empty());
        assert!(fixed.report.replica_trajectories.is_empty(), "static run has no controller");
    }
}

#[test]
fn elastic_rabin_karp_matches_static_across_configs() {
    let configs: [(usize, &str, usize, usize, usize); 3] = [
        (4096, "foobar", 3, 2, 512),
        (6000, "barfoo", 2, 2, 777),
        (600, "foobar", 2, 1, 7), // pathological segments straddling matches
    ];
    for (corpus_bytes, pattern, n, j, segment_bytes) in configs {
        let base = RabinKarpConfig {
            corpus_bytes,
            pattern: pattern.to_string(),
            hash_kernels: n,
            verify_kernels: j,
            segment_bytes,
            ..Default::default()
        };
        let elastic = run_rabin_karp(&base, RunOptions::default()).unwrap();
        let mut fixed_cfg = base.clone();
        fixed_cfg.static_degree = Some(n);
        let fixed = run_rabin_karp(&fixed_cfg, RunOptions::default()).unwrap();
        // Both sides are order-normalized (sorted, deduplicated), so
        // equality is exact.
        assert_eq!(
            elastic.matches, fixed.matches,
            "cfg ({corpus_bytes}, {pattern}, {n}, {j}, {segment_bytes}): elastic vs static"
        );
        let corpus = foobar_corpus(corpus_bytes);
        assert_eq!(elastic.matches, naive_matches(&corpus, pattern.as_bytes()));
        assert_eq!(
            elastic.report.replica_trajectories.len(),
            2,
            "hash + verify stages under one controller"
        );
    }
}

/// A replica body with no work: its stage is starvation-bound whenever it
/// has fewer arrivals than it can swallow (always, here).
struct Ident;
impl Replicable for Ident {
    type In = Item;
    type Out = Item;
    fn process(&mut self, v: Item) -> Item {
        v
    }
}

#[test]
fn coordinated_controller_scales_loaded_stage_and_refuses_starved_one() {
    // prod (2k items/s) → work (2 ms/item: overloaded) → relay (instant:
    // starved) → sink. The joint policy must replicate `work` and must
    // never scale up `relay` — the acceptance property of the coordinated
    // control plane, on a real scheduled pipeline.
    let rate = 2_000.0;
    let items = 2_500u64;
    let stage_cfg = |max: usize| ElasticStageConfig {
        policy: ElasticPolicy {
            target_rho: 0.7,
            band: 0.15,
            min_replicas: 1,
            max_replicas: max,
            cooldown_ticks: 4,
        },
        initial_replicas: 1,
        lane_capacity: 128,
        ..Default::default()
    };
    let count = Arc::new(AtomicU64::new(0));
    let c2 = count.clone();
    let mut expect = 0u64;
    // prod → work stage → relay stage → sink, one typed chain.
    let flow = Flow::new("coupled")
        .stream_defaults(StreamConfig::default().with_capacity(1024))
        .source::<Item>(Box::new(PacedProducer::from_rate_items_per_sec("prod", rate, items)))
        .elastic("work", stage_cfg(4), |_| PhasedServiceWorker::new(2_000_000, 2_000_000, 0))
        .unwrap()
        .elastic("relay", stage_cfg(4), |_| Ident)
        .unwrap()
        .sink(Box::new(ClosureSink::new("snk", move |v: Item| {
            assert_eq!(v, expect, "reordered delivery");
            expect += 1;
            c2.fetch_add(1, Ordering::Relaxed);
        })))
        .unwrap();

    let report = Session::run_flow(
        flow,
        RunOptions::default().with_elastic(ElasticConfig {
            tick: Duration::from_millis(5),
            buffer_advice: false,
            worker_budget: BudgetPolicy::Fixed(6),
            ..Default::default()
        }),
    )
    .unwrap();

    assert_eq!(count.load(Ordering::Relaxed), items, "item loss through the coupled stages");
    let ups_work = report
        .elastic_events
        .iter()
        .filter(|e| e.target == "work" && matches!(e.action, ElasticAction::ScaleUp { .. }))
        .count();
    assert!(
        ups_work >= 1,
        "overloaded stage never replicated: {:?}",
        report.elastic_events
    );
    let ups_relay = report
        .elastic_events
        .iter()
        .filter(|e| e.target == "relay" && matches!(e.action, ElasticAction::ScaleUp { .. }))
        .count();
    assert_eq!(
        ups_relay, 0,
        "starvation-bound stage was scaled up: {:?}",
        report.elastic_events
    );
    // Every audited scale-up carries its telemetry, and none fired on a
    // starvation-bound reading (the coordinated gate's invariant).
    for ev in report.elastic_events.iter() {
        if matches!(ev.action, ElasticAction::ScaleUp { .. }) {
            assert!(ev.mu_items > 0.0 && ev.lambda_items > 0.0, "{ev}");
            assert!(
                ev.pressure || ev.starved_frac < 0.5,
                "scale-up on a starved reading: {ev}"
            );
        }
    }
    // Both stages' trajectories are recorded; `work`'s is non-trivial.
    assert_eq!(report.replica_trajectories.len(), 2);
    let work_tr = report
        .replica_trajectories
        .iter()
        .find(|t| t.stage == "work")
        .expect("work trajectory");
    assert!(work_tr.points.len() >= 2, "no replication recorded: {work_tr:?}");
    // Blocked fractions were threaded through to the report.
    assert_eq!(report.stream_blocked.len(), 3, "one entry per stream");
}

#[test]
fn phase_shifting_rabin_karp_rescales_hash_stage_after_shift() {
    // The ROADMAP's phase-shifting **app** workload: a paced segment
    // stream feeds the real Rabin–Karp hash/verify stages, and a third of
    // the way through the run the pattern mix shifts from one pattern to
    // four of mixed lengths — per-segment hash cost ≈ 4×. The controller
    // must rescale the hash stage *after* the phase change (real rolling-
    // hash work, not a synthetic service-time stage), while matches stay
    // sound against the naive oracle.
    use streamflow::apps::rabin_karp::{
        MultiPatternVerifyWorker, PacedSegmenter, PhasedPatternHashWorker, Segment,
    };
    use streamflow::timing::TimeRef;

    let corpus = Arc::new(foobar_corpus(64 << 10));
    let segment_bytes = 8 << 10;
    let base = "foobar";
    let shifted = ["foobar", "foobarfoobarfoobar", "obarfooba", "arf"];

    // Calibrate the paced segment rate to the *measured* single-pattern
    // scan cost so the nominal utilization holds across debug/release
    // builds and loaded hosts: pre-shift ρ ≈ 0.45 (inside the hold band
    // at 1 replica), post-shift ρ ≈ 1.8 (well above it).
    let time = TimeRef::new();
    let mut probe = PhasedPatternHashWorker::new(&[base], &[base], u64::MAX);
    let seg_data = corpus[..segment_bytes].to_vec();
    let reps = 8u64;
    let t0 = time.now_ns();
    for _ in 0..reps {
        let _ = probe.process(Segment { offset: 0, data: seg_data.clone() });
    }
    let per_seg_ns = ((time.now_ns() - t0) / reps).max(20_000);
    let rate = 0.45 * 1.0e9 / per_seg_ns as f64; // segments/sec at ρ ≈ 0.45
    let secs = 3.0;
    let total_segments = ((rate * secs) as u64).max(60);
    let switch_at = time.now_ns() + ((secs / 3.0) * 1.0e9) as u64;

    let stage_cfg = |max: usize| ElasticStageConfig {
        policy: ElasticPolicy {
            target_rho: 0.7,
            band: 0.15,
            min_replicas: 1,
            max_replicas: max,
            cooldown_ticks: 4,
        },
        initial_replicas: 1,
        lane_capacity: 64,
        ..Default::default()
    };

    let found = Arc::new(std::sync::Mutex::new(Vec::new()));
    let f2 = found.clone();
    let hash_proto = PhasedPatternHashWorker::new(&[base], &shifted, switch_at);
    let verify_proto = MultiPatternVerifyWorker::new(corpus.clone(), &shifted);
    let flow = Flow::new("rk-phase")
        .stream_defaults(StreamConfig::default().with_capacity(256))
        .source::<Segment>(Box::new(PacedSegmenter::new(
            corpus.clone(),
            segment_bytes,
            base.len() - 1,
            rate,
            total_segments,
        )))
        .elastic("hash", stage_cfg(4), move |_| hash_proto.replica())
        .unwrap()
        .elastic("verify", stage_cfg(2), move |_| verify_proto.replica())
        .unwrap()
        .sink(Box::new(ClosureSink::new("snk", move |batch: Vec<usize>| {
            f2.lock().unwrap().extend(batch);
        })))
        .unwrap();

    let report = Session::run_flow(
        flow,
        RunOptions::default().with_elastic(ElasticConfig {
            tick: Duration::from_millis(5),
            buffer_advice: false,
            worker_budget: BudgetPolicy::Fixed(6),
            ..Default::default()
        }),
    )
    .unwrap();

    // The hash stage replicated, and only once the shifted mix was live —
    // the pre-shift load sits inside the hold band at one replica. 100 ms
    // of slack absorbs tick quantization around the switch instant.
    let hash_ups: Vec<_> = report
        .elastic_events
        .iter()
        .filter(|e| e.target == "hash" && matches!(e.action, ElasticAction::ScaleUp { .. }))
        .collect();
    assert!(
        !hash_ups.is_empty(),
        "pattern-mix shift never replicated the hash stage: {:?}",
        report.elastic_events
    );
    for ev in &hash_ups {
        assert!(
            ev.at_ns + 100_000_000 >= switch_at,
            "hash scale-up before the phase change (at {} ns, switch {} ns): {ev}",
            ev.at_ns,
            switch_at
        );
    }
    // Both app stages ran under one controller.
    assert_eq!(report.replica_trajectories.len(), 2, "hash + verify trajectories");

    // Matches stay sound: every reported position is a genuine match of
    // some pattern in the mix (no hash-collision leakage), and the base
    // pattern — active in both phases — is fully covered by the first
    // corpus pass.
    let mut got = std::mem::take(&mut *found.lock().unwrap());
    got.sort_unstable();
    got.dedup();
    let mut union: Vec<usize> = shifted
        .iter()
        .flat_map(|p| naive_matches(&corpus, p.as_bytes()))
        .collect();
    union.sort_unstable();
    union.dedup();
    assert!(got.iter().all(|p| union.binary_search(p).is_ok()), "false positives in matches");
    let base_expect = naive_matches(&corpus, base.as_bytes());
    assert!(
        base_expect.iter().all(|p| got.binary_search(p).is_ok()),
        "base-pattern matches lost ({} expected, {} found)",
        base_expect.len(),
        got.len()
    );
}
