//! The elastic control plane, outside-in: advice-function properties
//! (monotonicity, bounds), policy stability (no oscillation on constant
//! rates), and the closed loop end to end through the real scheduler —
//! replication under overload with an audited action trail and exact
//! order preservation.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use streamflow::classify::DistributionClass;
use streamflow::control::{parallelism_advice, BufferAdvisor, StreamRates};
use streamflow::elastic::{
    ElasticAction, ElasticConfig, ElasticStageConfig, ScaleDecision,
};
use streamflow::kernel::{ClosureSink, ClosureSource};
use streamflow::prelude::*;
use streamflow::testutil::{check, PropConfig};
use streamflow::workload::{Item, PacedProducer, PhasedServiceWorker};

fn cfg(cases: u32, seed: u64) -> PropConfig {
    PropConfig { cases, seed, max_shrink: 0 }
}

// ------------------------------------------------------------- advice --

#[test]
fn prop_buffer_advice_monotone_in_rho() {
    // For the closed-form M/M/1/C sizing, more utilization never means a
    // smaller recommended buffer.
    check(
        cfg(64, 21),
        |rng| {
            let mu = rng.uniform(500.0, 50_000.0);
            let a = rng.uniform(0.05, 0.98);
            let b = rng.uniform(0.05, 0.98);
            (mu, a.min(b), a.max(b))
        },
        |&(mu, lo, hi)| {
            let adv = BufferAdvisor::default();
            let cap = |rho: f64| {
                adv.advise(
                    StreamId(0),
                    StreamRates { lambda_items: Some(rho * mu), mu_items: Some(mu) },
                    DistributionClass::Exponential,
                )
                .unwrap()
                .capacity
            };
            cap(lo) <= cap(hi)
        },
    );
}

#[test]
fn prop_buffer_advice_respects_bounds() {
    // Every class (including the saturated λ ≥ μ path) stays within
    // [1, max_capacity].
    let classes = [
        DistributionClass::Exponential,
        DistributionClass::Deterministic,
        DistributionClass::Uniform,
        DistributionClass::Normal,
        DistributionClass::Unknown,
    ];
    check(
        cfg(96, 22),
        |rng| {
            (
                rng.uniform(10.0, 1.0e6),          // lambda
                rng.uniform(10.0, 1.0e6),          // mu
                rng.next_bounded(5) as usize,      // class index
            )
        },
        move |&(lambda, mu, ci)| {
            let adv = BufferAdvisor { max_capacity: 4096, ..Default::default() };
            let a = adv
                .advise(
                    StreamId(1),
                    StreamRates { lambda_items: Some(lambda), mu_items: Some(mu) },
                    classes[ci],
                )
                .unwrap();
            a.capacity >= 1 && a.capacity <= 4096
        },
    );
}

#[test]
fn prop_parallelism_advice_monotone_and_covering() {
    check(
        cfg(128, 23),
        |rng| {
            let a = rng.uniform(1.0, 1.0e6);
            let b = rng.uniform(1.0, 1.0e6);
            (
                a.min(b),
                a.max(b),
                rng.uniform(10.0, 1.0e5),  // mu per replica
                rng.uniform(0.3, 1.0),     // target rho
            )
        },
        |&(lo, hi, mu, t)| {
            let a_lo = parallelism_advice(lo, mu, t);
            let a_hi = parallelism_advice(hi, mu, t);
            // ≥ 1, monotone in λ, and the advised fleet covers the load
            // at the target utilization.
            a_lo >= 1 && a_lo <= a_hi && (a_hi as f64) * mu * t >= hi - 1e-6
        },
    );
}

// -------------------------------------------------------------- policy --

#[test]
fn prop_policy_never_oscillates_on_constant_trace() {
    // With constant λ and μ, the advice is a fixed point of the decision
    // rule: a 200-tick trace performs at most one scale action, from any
    // starting replica count — the hysteresis guarantee.
    check(
        cfg(128, 24),
        |rng| {
            (
                rng.uniform(50.0, 50_000.0),          // lambda
                rng.uniform(100.0, 10_000.0),         // mu
                1 + rng.next_bounded(8) as usize,     // starting replicas
                1 + rng.next_bounded(16) as usize,    // max replicas
            )
        },
        |&(lambda, mu, start, max)| {
            let p = ElasticPolicy {
                target_rho: 0.7,
                band: 0.15,
                min_replicas: 1,
                max_replicas: max,
                cooldown_ticks: 0,
            };
            let mut replicas = p.clamp(start);
            let mut actions = 0u32;
            for _ in 0..200 {
                let rho = lambda / (replicas as f64 * mu);
                match p.decide(rho, replicas, lambda, mu) {
                    ScaleDecision::Hold => {}
                    ScaleDecision::ScaleTo(n) => {
                        actions += 1;
                        replicas = n;
                    }
                }
            }
            actions <= 1
        },
    );
}

// ---------------------------------------------------- scheduler closed loop

#[test]
fn elastic_stage_preserves_order_under_scheduler() {
    // A pinned 3-replica stage inside a real scheduled run: every item
    // arrives exactly once, in order, and the replica workers are joined.
    let items = 20_000u64;
    let mut i = 0u64;

    struct AddOne;
    impl Replicable for AddOne {
        type In = u64;
        type Out = u64;
        fn process(&mut self, v: u64) -> u64 {
            v + 1
        }
    }
    let stage_cfg = ElasticStageConfig {
        policy: ElasticPolicy::pinned(3),
        initial_replicas: 3,
        lane_capacity: 64,
        ..Default::default()
    };

    let out = Arc::new(Mutex::new(Vec::new()));
    let o2 = out.clone();
    let flow = Flow::new("elastic-e2e")
        .stream_defaults(StreamConfig::default().with_capacity(1024))
        .source::<u64>(Box::new(ClosureSource::new("src", move || {
            i += 1;
            (i <= items).then_some(i)
        })))
        .elastic("add", stage_cfg, |_| AddOne)
        .unwrap()
        .sink(Box::new(ClosureSink::new("snk", move |v: u64| o2.lock().unwrap().push(v))))
        .unwrap();

    let report = Session::run_flow(flow, RunOptions::default()).unwrap();
    let v = out.lock().unwrap();
    assert_eq!(v.len(), items as usize, "item loss or duplication");
    for (idx, &x) in v.iter().enumerate() {
        assert_eq!(x, idx as u64 + 2, "out of order at {idx}");
    }
    let (pushes, pops) = report.stream_totals["add-merge.0 -> snk.0"];
    assert_eq!((pushes, pops), (items, items));
    // Pinned policy ⇒ the control plane had nothing to do.
    assert_eq!(report.scale_actions(), 0, "{:?}", report.elastic_events);
}

#[test]
fn controller_scales_up_under_overload_and_audits_actions() {
    // Offered 2k items/s into a 0.5k items/s replica: the control plane
    // must replicate (audited), order must survive, and the loop must not
    // flap.
    let rate = 2_000.0;
    let items = 2_500u64;
    let stage_cfg = ElasticStageConfig {
        policy: ElasticPolicy {
            target_rho: 0.7,
            band: 0.15,
            min_replicas: 1,
            max_replicas: 4,
            cooldown_ticks: 4,
        },
        initial_replicas: 1,
        lane_capacity: 128,
        ..Default::default()
    };
    let count = Arc::new(AtomicU64::new(0));
    let c2 = count.clone();
    let mut expect = 0u64;
    // Constant 2 ms (sleep-based) service — μ ≈ 500 items/s per replica.
    let flow = Flow::new("elastic-scale")
        .stream_defaults(StreamConfig::default().with_capacity(1024))
        .source::<Item>(Box::new(PacedProducer::from_rate_items_per_sec("prod", rate, items)))
        .elastic("work", stage_cfg, |_| PhasedServiceWorker::new(2_000_000, 2_000_000, 0))
        .unwrap()
        .sink(Box::new(ClosureSink::new("snk", move |v: Item| {
            assert_eq!(v, expect, "reordered delivery");
            expect += 1;
            c2.fetch_add(1, Ordering::Relaxed);
        })))
        .unwrap();

    let ecfg = ElasticConfig {
        tick: Duration::from_millis(5),
        buffer_advice: false,
        ..Default::default()
    };
    let report =
        Session::run_flow(flow, RunOptions::default().with_elastic(ecfg)).unwrap();

    assert_eq!(count.load(Ordering::Relaxed), items);
    let ups = report
        .elastic_events
        .iter()
        .filter(|e| matches!(e.action, ElasticAction::ScaleUp { .. }))
        .count();
    assert!(ups >= 1, "overload produced no scale-up: {:?}", report.elastic_events);
    assert!(
        report.scale_actions() <= 5,
        "control loop flapped ({} actions): {:?}",
        report.scale_actions(),
        report.elastic_events
    );
    // The audit trail carries the telemetry each decision was made on.
    for ev in report.elastic_events.iter().filter(|e| e.is_scale()) {
        assert!(ev.mu_items > 0.0 && ev.lambda_items > 0.0, "{ev}");
    }
}
