//! The supervision layer, outside-in: injected panics, stalls, and
//! overload driven through the real scheduler via the public API.
//!
//! Every test closes the conservation ledger — `items delivered +
//! items_lost + items_shed == items offered` — because the whole point
//! of audited degradation is that nothing ever disappears silently:
//! a lane restart loses exactly the in-flight item, an escalated lane
//! accounts for everything it drains, a poisoned stream counts its
//! stranded items, and a shedding source counts every drop.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use streamflow::elastic::ElasticConfig;
use streamflow::kernel::{ClosureSink, ClosureSource};
use streamflow::telemetry::ControlEvent;
use streamflow::workload::faults::{PanicAtItem, PanicRelay, SlowConsumer};
use streamflow::workload::{Item, PacedProducer, PhasedServiceWorker};
use streamflow::prelude::*;

/// One pinned supervised lane with the given restart budget.
fn one_lane(restart_budget: u32) -> ElasticStageConfig {
    ElasticStageConfig {
        policy: ElasticPolicy::pinned(1),
        initial_replicas: 1,
        lane_capacity: 64,
        supervisor: SupervisorPolicy::with_restart_budget(restart_budget),
        ..Default::default()
    }
}

// ------------------------------------------------------ lane supervision --

#[test]
fn lane_panic_restarts_under_backoff_and_audits_the_lost_item() {
    // A supervised lane panics on exactly one item. The lane must come
    // back (budget 2), every other item must arrive in order, and the
    // report must account for the single in-flight casualty.
    let items = 2_000u64;
    let out = Arc::new(Mutex::new(Vec::new()));
    let o2 = out.clone();
    let flow = Flow::new("restart")
        .stream_defaults(StreamConfig::default().with_capacity(1024))
        .source::<Item>(Box::new(PacedProducer::from_rate_items_per_sec(
            "prod", 50_000.0, items,
        )))
        .elastic("work", one_lane(2), |_| PanicAtItem::new(700))
        .unwrap()
        .sink(Box::new(ClosureSink::new("snk", move |v: Item| {
            o2.lock().unwrap().push(v)
        })))
        .unwrap();

    let report = Session::run_flow(flow, RunOptions::default()).unwrap();

    let v = out.lock().unwrap();
    let mut expect = (0..items).filter(|&x| x != 700);
    for (idx, &x) in v.iter().enumerate() {
        assert_eq!(Some(x), expect.next(), "order broken at {idx}");
    }
    assert_eq!(report.items_lost, 1, "exactly the in-flight item is lost");
    assert_eq!(v.len() as u64 + report.items_lost, items, "conservation");
    assert_eq!(report.faults.len(), 1, "{:?}", report.faults);
    let f = &report.faults[0];
    assert_eq!((f.target.as_str(), f.lane), ("work", Some(0)));
    assert!(!f.escalated, "one panic under budget 2 must not escalate");
    assert!(f.message.contains("panic at item 700"), "{}", f.message);
}

#[test]
fn restart_budget_exhaustion_escalates_and_conserves_items() {
    // The replica panics on *every* item from `trip` on, so the restart
    // budget (1) must burn down and escalate. The escalated lane keeps
    // draining — auditing each item as lost — so upstream never wedges
    // and the ledger closes exactly.
    struct PanicFrom {
        trip: Item,
    }
    impl Replicable for PanicFrom {
        type In = Item;
        type Out = Item;
        fn process(&mut self, v: Item) -> Item {
            if v >= self.trip {
                panic!("injected fault: panic from item {v}");
            }
            v
        }
    }

    let items = 400u64;
    let trip = 100u64;
    let count = Arc::new(AtomicU64::new(0));
    let c2 = count.clone();
    let mut expect = 0u64;
    let flow = Flow::new("escalate")
        .stream_defaults(StreamConfig::default().with_capacity(1024))
        .source::<Item>(Box::new(PacedProducer::from_rate_items_per_sec(
            "prod", 50_000.0, items,
        )))
        .elastic("work", one_lane(1), move |_| PanicFrom { trip })
        .unwrap()
        .sink(Box::new(ClosureSink::new("snk", move |v: Item| {
            assert_eq!(v, expect, "reordered delivery");
            expect += 1;
            c2.fetch_add(1, Ordering::Relaxed);
        })))
        .unwrap();

    let report = Session::run_flow(flow, RunOptions::default()).unwrap();

    let delivered = count.load(Ordering::Relaxed);
    assert_eq!(delivered, trip, "everything before the trip item survives");
    assert_eq!(report.items_lost, items - trip, "escalated drain is audited");
    assert_eq!(delivered + report.items_lost, items, "conservation");
    assert_eq!(report.faults.len(), 2, "{:?}", report.faults);
    assert!(
        !report.faults[0].escalated && report.faults[0].restarts == 0,
        "first panic is within budget: {:?}",
        report.faults[0]
    );
    assert!(
        report.faults[1].escalated && report.faults[1].restarts == 1,
        "second panic exhausts budget 1: {:?}",
        report.faults[1]
    );
}

// --------------------------------------------------- kernel panic poison --

#[test]
fn kernel_panic_poisons_streams_instead_of_hanging() {
    // A plain (unsupervised) kernel panics mid-run. The run must return
    // Ok — the panic is caught on the kernel thread, its streams are
    // poisoned so both neighbors unwedge, and everything the relay never
    // forwarded strands in the poisoned input queue, where the report
    // audits it.
    let n = 5_000u64;
    let mut i = 0u64;
    let delivered = Arc::new(AtomicU64::new(0));
    let d2 = delivered.clone();
    let flow = Flow::new("poison")
        .source::<Item>(Box::new(ClosureSource::new("src", move || {
            i += 1;
            (i <= n).then_some(i - 1)
        })))
        .then::<Item>(Box::new(PanicRelay::new("relay", 100)))
        .unwrap()
        .sink(Box::new(ClosureSink::new("snk", move |_: Item| {
            d2.fetch_add(1, Ordering::Relaxed);
        })))
        .unwrap();

    let report = Session::run_flow(flow, RunOptions::default()).unwrap();

    let got = delivered.load(Ordering::Relaxed);
    assert_eq!(got, 100, "the sink drains exactly what was relayed");
    let (produced, _) = report.stream_totals["src.0 -> relay.0"];
    assert_eq!(got + report.items_lost, produced, "conservation");
    assert_eq!(report.faults.len(), 1, "{:?}", report.faults);
    let f = &report.faults[0];
    assert_eq!((f.target.as_str(), f.lane, f.escalated), ("relay", None, true));
    assert!(f.message.contains("relay panic after 100 items"), "{}", f.message);
}

// ----------------------------------------------------------- deadline --

#[test]
fn deadline_force_closes_a_wedged_topology_with_partial_report() {
    // A consumer at 2 ms/item against a fast source can't finish 10k
    // items inside 250 ms. The deadline must force-close the topology
    // and hand back a partial — but honest — report, instead of hanging.
    let n = 10_000u64;
    let mut i = 0u64;
    let flow = Flow::new("deadline")
        .stream_defaults(StreamConfig::default().with_capacity(64))
        .source::<Item>(Box::new(ClosureSource::new("src", move || {
            i += 1;
            (i <= n).then_some(i - 1)
        })))
        .sink(Box::new(SlowConsumer::new("snk", Duration::from_millis(2))))
        .unwrap();

    let t0 = Instant::now();
    let report = Session::run_flow(
        flow,
        RunOptions::default().with_deadline(Duration::from_millis(250)),
    )
    .unwrap();
    let elapsed = t0.elapsed();

    assert!(elapsed < Duration::from_secs(10), "force-close took {elapsed:?}");
    assert!(report.deadline_hit, "the report must say it is partial");
    assert!(
        report.faults.iter().any(|f| f.target == "session" && f.escalated),
        "deadline abort must be audited: {:?}",
        report.faults
    );
    let (pushes, pops) = report.stream_totals["src.0 -> snk.0"];
    assert!(pops < n, "the run really was cut short");
    assert!(pushes >= pops);
}

// ------------------------------------------------------ stall watchdog --

#[test]
fn stall_watchdog_flags_a_wedged_elastic_stage() {
    // The lane worker goes dark for 200 ms mid-run. With a 5 ms control
    // tick and a 3-epoch watchdog, the controller must emit
    // StallSuspected for the stage — and the run must still finish with
    // zero loss once the worker wakes.
    struct StallOnce {
        at: Item,
        stall: Duration,
        hit: bool,
    }
    impl Replicable for StallOnce {
        type In = Item;
        type Out = Item;
        fn process(&mut self, v: Item) -> Item {
            if v == self.at && !self.hit {
                self.hit = true;
                std::thread::sleep(self.stall);
            }
            v
        }
    }

    let items = 6_000u64;
    let count = Arc::new(AtomicU64::new(0));
    let c2 = count.clone();
    let flow = Flow::new("stall")
        .stream_defaults(StreamConfig::default().with_capacity(256))
        .source::<Item>(Box::new(PacedProducer::from_rate_items_per_sec(
            "prod", 20_000.0, items,
        )))
        .elastic("work", one_lane(2), |_| StallOnce {
            at: 50,
            stall: Duration::from_millis(200),
            hit: false,
        })
        .unwrap()
        .sink(Box::new(ClosureSink::new("snk", move |_: Item| {
            c2.fetch_add(1, Ordering::Relaxed);
        })))
        .unwrap();

    let ecfg = ElasticConfig {
        tick: Duration::from_millis(5),
        buffer_advice: false,
        stall_epochs: 3,
        ..Default::default()
    };
    let report =
        Session::run_flow(flow, RunOptions::default().with_elastic(ecfg)).unwrap();

    assert_eq!(count.load(Ordering::Relaxed), items, "a stall loses nothing");
    assert!(
        report
            .control_events
            .iter()
            .any(|e| matches!(e, ControlEvent::StallSuspected { stage, .. } if stage == "work")),
        "the wedged stage must be flagged: {:?}",
        report.control_events
    );
    assert!(report.faults.is_empty() && report.items_lost == 0);
}

// ------------------------------------------------------- load shedding --

#[test]
fn budget_pinned_overload_sheds_load_and_conserves_the_ledger() {
    // 2k items/s offered into a 0.5k items/s lane, with the worker
    // budget pinned at 1 so scaling out is off the table. The controller
    // must degrade the source instead of letting the topology grind into
    // backpressure — and every shed item must be on the ledger.
    let items = 1_000u64;
    let shed = ShedControl::new();
    let count = Arc::new(AtomicU64::new(0));
    let c2 = count.clone();
    let stage_cfg = ElasticStageConfig {
        policy: ElasticPolicy {
            target_rho: 0.7,
            band: 0.15,
            min_replicas: 1,
            max_replicas: 4,
            cooldown_ticks: 0,
        },
        initial_replicas: 1,
        lane_capacity: 128,
        supervisor: SupervisorPolicy::default(),
        ..Default::default()
    };
    let flow = Flow::new("shed")
        .stream_defaults(StreamConfig::default().with_capacity(1024))
        .source::<Item>(Box::new(
            PacedProducer::from_rate_items_per_sec("prod", 2_000.0, items)
                .with_burst(10)
                .with_shedding(shed.clone()),
        ))
        .elastic("work", stage_cfg, |_| PhasedServiceWorker::new(2_000_000, 2_000_000, 0))
        .unwrap()
        .sink(Box::new(ClosureSink::new("snk", move |_: Item| {
            c2.fetch_add(1, Ordering::Relaxed);
        })))
        .unwrap();

    let ecfg = ElasticConfig {
        tick: Duration::from_millis(5),
        buffer_advice: false,
        shed_after_ticks: 2,
        worker_budget: BudgetPolicy::Fixed(1),
        ..Default::default()
    };
    let report = Session::run_flow(
        flow,
        RunOptions::default().with_elastic(ecfg).with_shedder("prod", shed.clone()),
    )
    .unwrap();

    let delivered = count.load(Ordering::Relaxed);
    assert!(report.items_shed > 0, "pinned overload must engage shedding");
    assert_eq!(report.items_shed, shed.shed_total());
    assert_eq!(delivered + report.items_shed, items, "conservation");
    assert!(
        report.control_events.iter().any(|e| matches!(e, ControlEvent::Shed { .. })),
        "degradation moves must be audited: {:?}",
        report.control_events
    );
    assert!(report.faults.is_empty() && report.items_lost == 0);
}
