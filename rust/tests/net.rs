//! Integration tests for the distributed data plane (`streamflow::net`):
//! codec robustness under arbitrary read fragmentation, fault semantics
//! (malformed frames and socket drops poison the edge — never a panic,
//! never a hang), single-process TCP loopback conservation, and the full
//! two-process sharded application runs (workers spawned through the
//! `rkworker` / `mmworker` subcommands of the real binary).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use streamflow::apps::{matmul, rabin_karp};
use streamflow::config::{MatmulConfig, RabinKarpConfig, StageTuning};
use streamflow::flow::{Inlet, Outlet, RunOptions, Session};
use streamflow::kernel::{Kernel, KernelContext, KernelStatus};
use streamflow::monitor::MonitorConfig;
use streamflow::net::{
    ConnSpec, Frame, FrameDecoder, NetEdgeStats, NetListener, NetSink, NetSource, Wire,
    WIRE_VERSION,
};
use streamflow::queue::StreamConfig;
use streamflow::rng::Xoshiro256pp;
use streamflow::topology::Topology;

// ---- helpers -----------------------------------------------------------

/// Source kernel: emits `0..n` as `u64` items in small bursts.
struct CountSource {
    n: u64,
    next: u64,
}

impl Kernel for CountSource {
    fn name(&self) -> &str {
        "count_source"
    }

    fn run(&mut self, ctx: &mut KernelContext) -> KernelStatus {
        if self.next >= self.n {
            return KernelStatus::Done;
        }
        let hi = (self.next + 64).min(self.n);
        let burst: Vec<u64> = (self.next..hi).collect();
        self.next = hi;
        let port = ctx.output::<u64>(0).expect("source port");
        if port.push_iter(burst).is_err() {
            return KernelStatus::Done;
        }
        KernelStatus::Continue
    }
}

/// Sink kernel: collects every received `u64`.
struct Collect {
    seen: Arc<Mutex<Vec<u64>>>,
}

impl Kernel for Collect {
    fn name(&self) -> &str {
        "collect"
    }

    fn run(&mut self, ctx: &mut KernelContext) -> KernelStatus {
        let port = ctx.input::<u64>(0).expect("collect input");
        match port.pop() {
            Some(v) => {
                self.seen.lock().unwrap().push(v);
                KernelStatus::Continue
            }
            None => KernelStatus::Done,
        }
    }
}

/// Client-side handshake over a raw socket (what a worker process does).
fn raw_handshake(conn: &mut TcpStream, topology_id: u64, edge_id: &str) {
    let hello = Frame::Hello {
        version: WIRE_VERSION,
        topology_id,
        edge_id: edge_id.to_string(),
    };
    conn.write_all(&hello.to_bytes()).unwrap();
    conn.flush().unwrap();
    // Await the ack (a full small frame; one read suffices on loopback,
    // but be robust to fragmentation anyway).
    let mut dec = FrameDecoder::new();
    let mut byte = [0u8; 64];
    loop {
        match dec.poll().unwrap() {
            Some(Frame::HelloAck) => return,
            Some(other) => panic!("expected HelloAck, got {other:?}"),
            None => {}
        }
        let n = conn.read(&mut byte).unwrap();
        assert!(n > 0, "listener hung up during handshake");
        dec.push_bytes(&byte[..n]);
    }
}

fn deadline_opts(secs: u64) -> RunOptions {
    let mut opts = RunOptions::default();
    opts.deadline = Some(Duration::from_secs(secs));
    opts
}

// ---- codec property tests (satellite: fuzz-ish round trips) ------------

#[test]
fn frame_codec_roundtrips_under_arbitrary_fragmentation() {
    let mut rng = Xoshiro256pp::new(0xC0DEC);
    for trial in 0..50 {
        // A pseudo-random mixed frame sequence.
        let mut frames: Vec<Frame> = Vec::new();
        frames.push(Frame::Hello {
            version: WIRE_VERSION,
            topology_id: rng.next_u64(),
            edge_id: format!("edge:{trial}"),
        });
        frames.push(Frame::HelloAck);
        let n_data = 1 + (rng.next_u64() % 8) as usize;
        for _ in 0..n_data {
            let count = (rng.next_u64() % 17) as usize;
            let items: Vec<Vec<usize>> = (0..count)
                .map(|_| {
                    let len = (rng.next_u64() % 9) as usize;
                    (0..len).map(|_| rng.next_u64() as usize).collect()
                })
                .collect();
            let mut body = Vec::new();
            streamflow::net::encode_batch(&items, &mut body);
            frames.push(Frame::Data {
                pushes: rng.next_u64(),
                blocked_ns: rng.next_u64(),
                count: count as u32,
                body,
            });
        }
        frames.push(Frame::Fin { poisoned: trial % 2 == 0 });

        let mut wire = Vec::new();
        for f in &frames {
            f.encode(&mut wire);
        }

        // Replay under a random fragmentation schedule (trial 0: the
        // 1-byte dribble — every torn-header offset gets exercised).
        let mut dec = FrameDecoder::new();
        let mut got = Vec::new();
        let mut at = 0usize;
        while at < wire.len() {
            let step = if trial == 0 { 1 } else { 1 + (rng.next_u64() % 11) as usize };
            let hi = (at + step).min(wire.len());
            dec.push_bytes(&wire[at..hi]);
            at = hi;
            while let Some(f) = dec.poll().expect("well-formed stream") {
                got.push(f);
            }
        }
        assert_eq!(got, frames, "trial {trial}");
        assert_eq!(dec.pending_bytes(), 0, "trial {trial}: trailing bytes");
    }
}

#[test]
fn data_frame_bodies_roundtrip_item_batches() {
    let mut rng = Xoshiro256pp::new(0xBA7C4);
    for _ in 0..100 {
        let count = (rng.next_u64() % 33) as usize;
        let items: Vec<Vec<usize>> = (0..count)
            .map(|_| {
                let len = (rng.next_u64() % 13) as usize;
                (0..len).map(|_| rng.next_u64() as usize).collect()
            })
            .collect();
        let mut body = Vec::new();
        streamflow::net::encode_batch(&items, &mut body);
        let back: Vec<Vec<usize>> = streamflow::net::decode_batch(count, &body).unwrap();
        assert_eq!(back, items);
        // A truncated body must error, not mis-decode (torn write).
        if !body.is_empty() {
            assert!(streamflow::net::decode_batch::<Vec<usize>>(count, &body[..body.len() - 1])
                .is_err());
        }
    }
}

#[test]
fn segment_and_block_wire_impls_roundtrip() {
    let seg = rabin_karp::Segment { offset: 12345, data: b"foobarfoo".to_vec() };
    let mut buf = Vec::new();
    seg.encode(&mut buf);
    let back =
        rabin_karp::Segment::decode(&mut streamflow::net::WireReader::new(&buf)).unwrap();
    assert_eq!(back.offset, seg.offset);
    assert_eq!(back.data, seg.data);

    let blk = matmul::RowBlock { start: 32, rows: 4, data: vec![1.5f32, -2.25, 0.0, 7.75] };
    let mut buf = Vec::new();
    blk.encode(&mut buf);
    let back = matmul::RowBlock::decode(&mut streamflow::net::WireReader::new(&buf)).unwrap();
    assert_eq!((back.start, back.rows), (blk.start, blk.rows));
    assert_eq!(back.data, blk.data);
}

// ---- fault semantics ---------------------------------------------------

#[test]
fn malformed_frame_poisons_edge_instead_of_panicking() {
    let tid = streamflow::net::topology_id(&[b"malformed-test"]);
    let listener = NetListener::bind("127.0.0.1:0", tid).unwrap();
    let spec = listener.expect_edge("mal");
    let addr = listener.local_addr();

    let client = std::thread::spawn(move || {
        let mut conn = TcpStream::connect(addr).unwrap();
        raw_handshake(&mut conn, tid, "mal");
        // A structurally valid envelope with an unknown kind byte.
        let mut junk = Vec::new();
        junk.extend_from_slice(&8u32.to_le_bytes());
        junk.push(99); // no such frame kind
        junk.extend_from_slice(&[0xAB; 7]);
        conn.write_all(&junk).unwrap();
        conn.flush().unwrap();
        // Hold the socket open: the *decoder*, not EOF, must kill the edge.
        std::thread::sleep(Duration::from_millis(300));
    });

    let stats = NetEdgeStats::new("mal");
    let seen = Arc::new(Mutex::new(Vec::new()));
    let mut topo = Topology::new("malformed");
    let src = topo.add_kernel(Box::new(NetSource::<u64>::new(spec, stats.clone())));
    let snk = topo.add_kernel(Box::new(Collect { seen: seen.clone() }));
    topo.connect(Outlet::<u64>::new(src, 0), Inlet::new(snk, 0), StreamConfig::default())
        .unwrap();
    topo.register_net_edge(stats.clone());

    let report = Session::run(topo, deadline_opts(10)).unwrap();
    client.join().unwrap();
    assert!(!report.deadline_hit, "poison must end the run, not the deadline");
    assert!(stats.is_poisoned(), "malformed frame must poison the edge");
    assert!(
        report.faults.iter().any(|f| f.target.contains("mal")),
        "expected a FaultRecord for the poisoned edge, got {:?}",
        report.faults
    );
}

#[test]
fn socket_drop_mid_stream_yields_fault_record_not_hang() {
    let tid = streamflow::net::topology_id(&[b"drop-test"]);
    let listener = NetListener::bind("127.0.0.1:0", tid).unwrap();
    let spec = listener.expect_edge("drop");
    let addr = listener.local_addr();

    let client = std::thread::spawn(move || {
        let mut conn = TcpStream::connect(addr).unwrap();
        raw_handshake(&mut conn, tid, "drop");
        // One valid batch, then vanish without a FIN frame.
        let items: Vec<u64> = vec![7, 8, 9];
        let mut body = Vec::new();
        streamflow::net::encode_batch(&items, &mut body);
        let frame = Frame::Data { pushes: 3, blocked_ns: 0, count: 3, body };
        conn.write_all(&frame.to_bytes()).unwrap();
        conn.flush().unwrap();
        // Dropping `conn` closes the socket abruptly.
    });

    let stats = NetEdgeStats::new("drop");
    let seen = Arc::new(Mutex::new(Vec::new()));
    let mut topo = Topology::new("dropped");
    let src = topo.add_kernel(Box::new(NetSource::<u64>::new(spec, stats.clone())));
    let snk = topo.add_kernel(Box::new(Collect { seen: seen.clone() }));
    topo.connect(Outlet::<u64>::new(src, 0), Inlet::new(snk, 0), StreamConfig::default())
        .unwrap();
    topo.register_net_edge(stats.clone());

    let report = Session::run(topo, deadline_opts(10)).unwrap();
    client.join().unwrap();
    assert!(!report.deadline_hit, "drop must poison promptly, not wait out the deadline");
    assert!(stats.is_poisoned());
    assert!(
        report.faults.iter().any(|f| f.message.contains("FIN")),
        "expected a dropped-without-FIN fault, got {:?}",
        report.faults
    );
    // The batch delivered before the drop still arrived (partial result).
    assert_eq!(*seen.lock().unwrap(), vec![7, 8, 9]);
}

// ---- loopback conservation --------------------------------------------

#[test]
fn loopback_edge_conserves_items_and_folds_remote_counters() {
    const N: u64 = 10_000;
    let tid = streamflow::net::topology_id(&[b"loopback-test"]);
    let listener = NetListener::bind("127.0.0.1:0", tid).unwrap();
    let accept_spec = listener.expect_edge("loop");
    let connect_spec = ConnSpec::Connect {
        addr: listener.local_addr().to_string(),
        topology_id: tid,
        edge_id: "loop".to_string(),
        retries: 10,
    };

    // One topology whose middle edge is a real TCP connection:
    //   CountSource → NetSink ⇉ socket ⇉ NetSource → Collect
    let sink_stats = NetEdgeStats::new("loop:tx");
    let source_stats = NetEdgeStats::new("loop:rx");
    let seen = Arc::new(Mutex::new(Vec::new()));
    let mut topo = Topology::new("loopback");
    let gen = topo.add_kernel(Box::new(CountSource { n: N, next: 0 }));
    let tx = topo.add_kernel(Box::new(NetSink::<u64>::new(connect_spec, sink_stats.clone())));
    topo.connect(Outlet::<u64>::new(gen, 0), Inlet::new(tx, 0), StreamConfig::default())
        .unwrap();
    let rx = topo.add_kernel(Box::new(NetSource::<u64>::new(accept_spec, source_stats.clone())));
    let snk = topo.add_kernel(Box::new(Collect { seen: seen.clone() }));
    topo.connect(Outlet::<u64>::new(rx, 0), Inlet::new(snk, 0), StreamConfig::default())
        .unwrap();
    topo.register_net_edge(sink_stats.clone());
    topo.register_net_edge(source_stats.clone());

    let report = Session::run(topo, deadline_opts(30)).unwrap();
    assert!(!report.deadline_hit);
    assert!(report.faults.is_empty(), "clean run: {:?}", report.faults);

    // Exact conservation across the boundary at end of run:
    // sent == received, nothing in flight, and the piggybacked remote
    // push counter agrees with the local receive count.
    let mut got = seen.lock().unwrap().clone();
    got.sort_unstable();
    assert_eq!(got, (0..N).collect::<Vec<u64>>());
    assert_eq!(sink_stats.sent(), N);
    assert_eq!(source_stats.received(), N);
    assert_eq!(source_stats.remote_pushes(), N);
    assert_eq!(source_stats.in_flight(), 0);
    assert!(source_stats.frames() > 0);
    assert_eq!(report.items_lost, 0);
}

// ---- two-process sharded application runs ------------------------------

fn worker_bin_env() {
    // The coordinator re-invokes the real binary; point it at the one
    // cargo built for this test profile.
    std::env::set_var("SF_WORKER_BIN", env!("CARGO_BIN_EXE_streamflow"));
}

#[test]
fn sharded_rabin_karp_is_exact_across_two_worker_processes() {
    worker_bin_env();
    let cfg = RabinKarpConfig {
        corpus_bytes: 2 << 20,
        pattern: "foobar".to_string(),
        segment_bytes: 16 << 10,
        hash_kernels: 2,
        verify_kernels: 4,
        // Aggressive verify tuning: any measurable utilization upscales,
        // so the timeline reliably shows the controller rescaling the
        // stage whose upstream is a NetSource.
        verify_tuning: StageTuning {
            target_rho: 0.01,
            band: 0.005,
            cooldown_ticks: 1,
            restart_budget: None,
        },
        ..Default::default()
    };
    let mut opts = RunOptions::monitored(MonitorConfig::practical());
    opts.deadline = Some(Duration::from_secs(120));
    let run = rabin_karp::run_rabin_karp_sharded(&cfg, 2, "127.0.0.1:0", opts).unwrap();

    // Exact result: the distributed pipeline found every match.
    let corpus = rabin_karp::foobar_corpus(cfg.corpus_bytes);
    let expect = rabin_karp::naive_matches(&corpus, cfg.pattern.as_bytes());
    assert_eq!(run.matches, expect, "sharded result differs from the oracle");

    // End-to-end conservation at the coordinator:
    // delivered + items_lost + items_shed == offered with zero loss.
    assert!(!run.report.deadline_hit, "run must drain, not time out");
    assert!(run.report.faults.is_empty(), "clean run: {:?}", run.report.faults);
    assert_eq!(run.report.items_lost, 0);
    assert_eq!(run.report.items_shed, 0);
    for (label, (pushes, pops)) in &run.report.stream_totals {
        assert_eq!(pushes, pops, "stream {label} left items behind");
    }

    // Both worker processes exited cleanly.
    assert_eq!(run.workers.len(), 2);
    for w in &run.workers {
        assert!(w.success, "worker pid {} failed: {:?}", w.pid, w.code);
    }

    // The controller rescaled the verify stage (remote-fed upstream).
    assert!(
        !run.report.scaling_timeline().is_empty(),
        "expected a scaling timeline from the coordinator's controller"
    );
    let upscaled = run
        .report
        .replica_trajectories
        .iter()
        .any(|tr| tr.stage == "verify" && tr.points.iter().any(|&(_, r)| r > 1));
    assert!(
        upscaled,
        "verify stage never rescaled: {:?}",
        run.report.scaling_timeline()
    );
}

#[test]
fn sharded_matmul_is_exact_across_two_worker_processes() {
    worker_bin_env();
    let cfg = MatmulConfig { n: 128, dot_kernels: 2, block_rows: 16, ..Default::default() };
    let mut opts = RunOptions::monitored(MonitorConfig::practical());
    opts.deadline = Some(Duration::from_secs(120));
    let run = matmul::run_matmul_sharded(&cfg, 2, "127.0.0.1:0", opts).unwrap();

    let a = matmul::random_matrix(cfg.n, cfg.seed);
    let b = matmul::random_matrix(cfg.n, cfg.seed ^ 0xFEED);
    let expect = matmul::matmul_ref(&a, &b, cfg.n);
    assert_eq!(run.c.len(), expect.len());
    for (i, (&got, &want)) in run.c.iter().zip(&expect).enumerate() {
        assert!((got - want).abs() < 1e-3, "C[{i}] = {got} vs {want}");
    }
    assert!(!run.report.deadline_hit);
    assert!(run.report.faults.is_empty(), "clean run: {:?}", run.report.faults);
    assert_eq!(run.report.items_lost, 0);
    assert_eq!(run.workers.len(), 2);
    for w in &run.workers {
        assert!(w.success, "worker pid {} failed: {:?}", w.pid, w.code);
    }
    assert_eq!(run.reduce_streams.len(), 2, "one instrumented stream per shard");
}
