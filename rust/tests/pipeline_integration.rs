//! Integration: multi-kernel topologies under the real scheduler —
//! fan-out/fan-in, chains, monitored runs, and shutdown edge cases.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use streamflow::kernel::{ClosureSink, ClosureSource, Kernel, KernelContext, KernelStatus};
use streamflow::monitor::MonitorConfig;
use streamflow::prelude::*;
use streamflow::queue::{PopResult, StreamConfig};

/// Round-robin splitter: one input, `n` outputs.
struct Splitter {
    n: usize,
    next: usize,
}

impl Kernel for Splitter {
    fn name(&self) -> &str {
        "split"
    }
    fn run(&mut self, ctx: &mut KernelContext) -> KernelStatus {
        match ctx.input::<u64>(0).unwrap().pop() {
            Some(v) => {
                let port = ctx.output::<u64>(self.next).unwrap();
                self.next = (self.next + 1) % self.n;
                if port.push(v).is_err() {
                    return KernelStatus::Done;
                }
                KernelStatus::Continue
            }
            None => KernelStatus::Done,
        }
    }
}

/// N-input merger into a shared counter.
struct Merger {
    sum: Arc<AtomicU64>,
    count: Arc<AtomicU64>,
}

impl Kernel for Merger {
    fn name(&self) -> &str {
        "merge"
    }
    fn run(&mut self, ctx: &mut KernelContext) -> KernelStatus {
        let mut all_closed = true;
        let mut any = false;
        for i in 0..ctx.num_inputs() {
            match ctx.input::<u64>(i).unwrap().try_pop() {
                PopResult::Item(v) => {
                    self.sum.fetch_add(v, Ordering::Relaxed);
                    self.count.fetch_add(1, Ordering::Relaxed);
                    any = true;
                    all_closed = false;
                }
                PopResult::Empty => all_closed = false,
                PopResult::Closed => {}
            }
        }
        if all_closed {
            KernelStatus::Done
        } else if any {
            KernelStatus::Continue
        } else {
            KernelStatus::Stall
        }
    }
}

#[test]
fn fanout_fanin_delivers_every_item_once() {
    let n_workers = 4;
    let items = 100_000u64;
    let mut topo = Topology::new("fanout");
    let mut i = 0u64;
    let src = topo.add_kernel(Box::new(ClosureSource::new("src", move || {
        i += 1;
        (i <= items).then_some(i)
    })));
    let split = topo.add_kernel(Box::new(Splitter { n: n_workers, next: 0 }));
    topo.connect(Outlet::<u64>::new(src, 0), Inlet::new(split, 0), StreamConfig::default())
        .unwrap();

    let sum = Arc::new(AtomicU64::new(0));
    let count = Arc::new(AtomicU64::new(0));
    let merge = topo.add_kernel(Box::new(Merger { sum: sum.clone(), count: count.clone() }));

    for w in 0..n_workers {
        // Identity worker kernel.
        struct Identity;
        impl Kernel for Identity {
            fn name(&self) -> &str {
                "worker"
            }
            fn run(&mut self, ctx: &mut KernelContext) -> KernelStatus {
                match ctx.input::<u64>(0).unwrap().pop() {
                    Some(v) => {
                        if ctx.output::<u64>(0).unwrap().push(v).is_err() {
                            return KernelStatus::Done;
                        }
                        KernelStatus::Continue
                    }
                    None => KernelStatus::Done,
                }
            }
        }
        let worker = topo.add_kernel(Box::new(Identity));
        topo.connect(
            Outlet::<u64>::new(split, w),
            Inlet::new(worker, 0),
            StreamConfig::default().with_capacity(64),
        )
        .unwrap();
        topo.connect(
            Outlet::<u64>::new(worker, 0),
            Inlet::new(merge, w),
            StreamConfig::default().with_capacity(64),
        )
        .unwrap();
    }

    let report = Session::run(topo, RunOptions::default()).unwrap();
    assert_eq!(count.load(Ordering::Relaxed), items);
    assert_eq!(sum.load(Ordering::Relaxed), items * (items + 1) / 2);
    assert!(report.wall_ns > 0);
}

#[test]
fn deep_chain_preserves_order_and_count() {
    let depth = 8;
    let items = 20_000u64;
    let mut topo = Topology::new("chain");
    let mut i = 0u64;
    let src = topo.add_kernel(Box::new(ClosureSource::new("src", move || {
        i += 1;
        (i <= items).then_some(i)
    })));
    struct Inc;
    impl Kernel for Inc {
        fn name(&self) -> &str {
            "inc"
        }
        fn run(&mut self, ctx: &mut KernelContext) -> KernelStatus {
            match ctx.input::<u64>(0).unwrap().pop() {
                Some(v) => {
                    if ctx.output::<u64>(0).unwrap().push(v + 1).is_err() {
                        return KernelStatus::Done;
                    }
                    KernelStatus::Continue
                }
                None => KernelStatus::Done,
            }
        }
    }
    let mut prev = src;
    for _ in 0..depth {
        let k = topo.add_kernel(Box::new(Inc));
        topo.connect(
            Outlet::<u64>::new(prev, 0),
            Inlet::new(k, 0),
            StreamConfig::default().with_capacity(32),
        )
        .unwrap();
        prev = k;
    }
    let out = Arc::new(Mutex::new(Vec::new()));
    let out2 = out.clone();
    let snk = topo
        .add_kernel(Box::new(ClosureSink::new("snk", move |v: u64| out2.lock().unwrap().push(v))));
    topo.connect(Outlet::<u64>::new(prev, 0), Inlet::new(snk, 0), StreamConfig::default().with_capacity(32))
        .unwrap();

    Session::run(topo, RunOptions::default()).unwrap();
    let v = out.lock().unwrap();
    assert_eq!(v.len(), items as usize);
    for (idx, &x) in v.iter().enumerate() {
        assert_eq!(x, idx as u64 + 1 + depth as u64);
    }
}

#[test]
fn tiny_capacity_one_queue_still_flows() {
    // Capacity 1 forces constant blocking on both ends — the worst case
    // for the queue protocol and the blocked-flag bookkeeping.
    let mut topo = Topology::new("cap1");
    let items = 10_000u64;
    let mut i = 0u64;
    let src = topo.add_kernel(Box::new(ClosureSource::new("src", move || {
        i += 1;
        (i <= items).then_some(i)
    })));
    let n = Arc::new(AtomicU64::new(0));
    let n2 = n.clone();
    let snk = topo.add_kernel(Box::new(ClosureSink::new("snk", move |_: u64| {
        n2.fetch_add(1, Ordering::Relaxed);
    })));
    let sid = topo
        .connect(Outlet::<u64>::new(src, 0), Inlet::new(snk, 0), StreamConfig::default().with_capacity(1))
        .unwrap();
    let report = Session::run(topo, RunOptions::default()).unwrap();
    assert_eq!(n.load(Ordering::Relaxed), items);
    let (pushes, pops) = report.stream_totals[&format!("src.0 -> snk.{}", 0)];
    assert_eq!(pushes, items);
    assert_eq!(pops, items);
    let _ = sid;
}

#[test]
fn monitored_app_shuts_down_cleanly_even_when_too_short_to_converge() {
    let mut topo = Topology::new("short");
    let mut i = 0u64;
    let src = topo.add_kernel(Box::new(ClosureSource::new("src", move || {
        i += 1;
        (i <= 100).then_some(i)
    })));
    let snk = topo.add_kernel(Box::new(ClosureSink::new("snk", |_: u64| {})));
    topo.connect(Outlet::<u64>::new(src, 0), Inlet::new(snk, 0), StreamConfig::default())
        .unwrap();
    let report =
        Session::run(topo, RunOptions::monitored(MonitorConfig::practical())).unwrap();
    // 100 items flow in microseconds; the monitor must not hang the run.
    assert!(report.estimates.is_empty() || !report.estimates.is_empty()); // no panic/hang
    let (pushes, pops) = report.stream_totals["src.0 -> snk.0"];
    assert_eq!((pushes, pops), (100, 100));
}

#[test]
fn empty_source_closes_immediately() {
    let mut topo = Topology::new("empty");
    let src = topo.add_kernel(Box::new(ClosureSource::new("src", move || None::<u64>)));
    let n = Arc::new(AtomicU64::new(0));
    let n2 = n.clone();
    let snk = topo.add_kernel(Box::new(ClosureSink::new("snk", move |_: u64| {
        n2.fetch_add(1, Ordering::Relaxed);
    })));
    topo.connect(Outlet::<u64>::new(src, 0), Inlet::new(snk, 0), StreamConfig::default())
        .unwrap();
    Session::run(topo, RunOptions::default()).unwrap();
    assert_eq!(n.load(Ordering::Relaxed), 0);
}

#[test]
fn invalid_topology_fails_before_spawning() {
    let mut topo = Topology::new("bad");
    let src = topo.add_kernel(Box::new(ClosureSource::new("src", move || None::<u64>)));
    let snk = topo.add_kernel(Box::new(ClosureSink::new("snk", |_: u64| {})));
    // Output port 2 with 0/1 missing → validation error at run().
    topo.connect(Outlet::<u64>::new(src, 2), Inlet::new(snk, 0), StreamConfig::default())
        .unwrap();
    assert!(Session::run(topo, RunOptions::default()).is_err());
}

#[test]
fn heterogeneous_item_types_coexist() {
    // u64 stream and String stream in one topology.
    struct Stringify;
    impl Kernel for Stringify {
        fn name(&self) -> &str {
            "stringify"
        }
        fn run(&mut self, ctx: &mut KernelContext) -> KernelStatus {
            match ctx.input::<u64>(0).unwrap().pop() {
                Some(v) => {
                    if ctx.output::<String>(0).unwrap().push(format!("#{v}")).is_err() {
                        return KernelStatus::Done;
                    }
                    KernelStatus::Continue
                }
                None => KernelStatus::Done,
            }
        }
    }
    let mut topo = Topology::new("hetero");
    let mut i = 0u64;
    let src = topo.add_kernel(Box::new(ClosureSource::new("src", move || {
        i += 1;
        (i <= 5).then_some(i)
    })));
    let mid = topo.add_kernel(Box::new(Stringify));
    let out = Arc::new(Mutex::new(Vec::new()));
    let out2 = out.clone();
    let snk = topo.add_kernel(Box::new(ClosureSink::new("snk", move |s: String| {
        out2.lock().unwrap().push(s)
    })));
    topo.connect(Outlet::<u64>::new(src, 0), Inlet::new(mid, 0), StreamConfig::default())
        .unwrap();
    topo.connect(
        Outlet::<String>::new(mid, 0),
        Inlet::new(snk, 0),
        StreamConfig::default().with_item_bytes(16),
    )
    .unwrap();
    Session::run(topo, RunOptions::default()).unwrap();
    assert_eq!(*out.lock().unwrap(), vec!["#1", "#2", "#3", "#4", "#5"]);
}

// ------------------------------------------------- mid-graph fan-in --
// The two previously untested fan-in shapes (ROADMAP PR-4 follow-up):
// an elastic stage's Merge feeding a downstream *kernel* (not a sink),
// and `FlowFan::merge` collapsing a static fan through a kernel that
// itself has an output.

#[test]
fn elastic_merge_into_midgraph_kernel_preserves_order_and_totals() {
    use streamflow::elastic::ElasticStageConfig;
    use streamflow::flow::Flow;

    struct AddOne;
    impl streamflow::elastic::Replicable for AddOne {
        type In = u64;
        type Out = u64;
        fn process(&mut self, v: u64) -> u64 {
            v + 1
        }
    }
    /// The mid-graph consumer of the stage's merge output.
    struct Tenfold;
    impl Kernel for Tenfold {
        fn name(&self) -> &str {
            "relay"
        }
        fn run(&mut self, ctx: &mut KernelContext) -> KernelStatus {
            match ctx.input::<u64>(0).unwrap().pop() {
                Some(v) => {
                    if ctx.output::<u64>(0).unwrap().push(v * 10).is_err() {
                        return KernelStatus::Done;
                    }
                    KernelStatus::Continue
                }
                None => KernelStatus::Done,
            }
        }
    }

    let items = 30_000u64;
    let mut i = 0u64;
    let out = Arc::new(Mutex::new(Vec::new()));
    let o2 = out.clone();
    let flow = Flow::new("merge-mid")
        .stream_defaults(StreamConfig::default().with_capacity(256))
        .source::<u64>(Box::new(ClosureSource::new("src", move || {
            i += 1;
            (i <= items).then_some(i)
        })))
        .elastic(
            "work",
            ElasticStageConfig {
                policy: ElasticPolicy::pinned(3),
                initial_replicas: 3,
                lane_capacity: 64,
                ..Default::default()
            },
            |_| AddOne,
        )
        .unwrap()
        .then::<u64>(Box::new(Tenfold))
        .unwrap()
        .sink(Box::new(ClosureSink::new("snk", move |v: u64| o2.lock().unwrap().push(v))))
        .unwrap();

    let report =
        Session::run_flow(flow, RunOptions::monitored(MonitorConfig::practical())).unwrap();
    let v = out.lock().unwrap();
    assert_eq!(v.len(), items as usize, "item loss through merge → kernel");
    for (idx, &x) in v.iter().enumerate() {
        assert_eq!(x, (idx as u64 + 2) * 10, "order broken at {idx}");
    }
    // The merge → relay edge is an ordinary instrumented stream with the
    // merge kernel as its producer.
    let (pushes, pops) = report.stream_totals["work-merge.0 -> relay.0"];
    assert_eq!((pushes, pops), (items, items));
}

#[test]
fn flowfan_merge_into_midgraph_kernel_delivers_everything() {
    use streamflow::flow::Flow;

    /// Round-robin 3-port source.
    struct Rr {
        left: u64,
        next: usize,
    }
    impl Kernel for Rr {
        fn name(&self) -> &str {
            "rr"
        }
        fn run(&mut self, ctx: &mut KernelContext) -> KernelStatus {
            if self.left == 0 {
                return KernelStatus::Done;
            }
            self.left -= 1;
            let p = self.next;
            self.next = (self.next + 1) % 3;
            if ctx.output::<u64>(p).unwrap().push(self.left).is_err() {
                return KernelStatus::Done;
            }
            KernelStatus::Continue
        }
    }
    /// 3-in/1-out fan-in kernel — the previously untested non-sink
    /// `FlowFan::merge` shape.
    struct Funnel;
    impl Kernel for Funnel {
        fn name(&self) -> &str {
            "funnel"
        }
        fn run(&mut self, ctx: &mut KernelContext) -> KernelStatus {
            let mut all_closed = true;
            let mut any = false;
            for p in 0..ctx.num_inputs() {
                match ctx.input::<u64>(p).unwrap().try_pop() {
                    PopResult::Item(v) => {
                        if ctx.output::<u64>(0).unwrap().push(v).is_err() {
                            return KernelStatus::Done;
                        }
                        any = true;
                        all_closed = false;
                    }
                    PopResult::Empty => all_closed = false,
                    PopResult::Closed => {}
                }
            }
            if all_closed {
                KernelStatus::Done
            } else if any {
                KernelStatus::Continue
            } else {
                KernelStatus::Stall
            }
        }
    }

    let items = 9_999u64;
    let sum = Arc::new(AtomicU64::new(0));
    let count = Arc::new(AtomicU64::new(0));
    let (s2, c2) = (sum.clone(), count.clone());
    let flow = Flow::new("fan-merge-mid")
        .stream_defaults(StreamConfig::default().with_capacity(128))
        .source::<u64>(Box::new(Rr { left: items, next: 0 }))
        .tee(3)
        .then_each::<u64, _>(|_| {
            struct Inc;
            impl Kernel for Inc {
                fn name(&self) -> &str {
                    "inc"
                }
                fn run(&mut self, ctx: &mut KernelContext) -> KernelStatus {
                    match ctx.input::<u64>(0).unwrap().pop() {
                        Some(v) => {
                            if ctx.output::<u64>(0).unwrap().push(v + 1).is_err() {
                                return KernelStatus::Done;
                            }
                            KernelStatus::Continue
                        }
                        None => KernelStatus::Done,
                    }
                }
            }
            Box::new(Inc)
        })
        .unwrap()
        .merge::<u64>(Box::new(Funnel))
        .unwrap()
        .sink(Box::new(ClosureSink::new("snk", move |v: u64| {
            s2.fetch_add(v, Ordering::Relaxed);
            c2.fetch_add(1, Ordering::Relaxed);
        })))
        .unwrap();

    let topo = flow.finish();
    // The fan-in kernel's ports are contiguous: inputs 0..3, output 0.
    topo.validate().unwrap();
    Session::run(topo, RunOptions::default()).unwrap();
    assert_eq!(count.load(Ordering::Relaxed), items);
    // Items 0..items each incremented once.
    assert_eq!(sum.load(Ordering::Relaxed), items * (items - 1) / 2 + items);
}
