//! Host-aware placement & dynamic worker budgets, outside-in: the
//! controller must shrink the coordinated replica total **within one
//! control epoch** of synthetic host load arriving and restore it after
//! the load clears (budget timeline audited in the report); the
//! host-aware path must degrade to an annotated ceiling without
//! telemetry; and `PlacementPolicy::Pack` must never change results —
//! only where threads run — including on hosts that refuse
//! `sched_setaffinity` (the CI fallback lane runs this file with
//! `SF_NO_AFFINITY=1`).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

// `ElasticStage` is needed in scope for `stage.replicas()` calls on the
// shared ScriptedStage double.
use streamflow::elastic::{
    ElasticConfig, ElasticController, ElasticStage, ElasticStageConfig, StageBinding,
    StreamBinding,
};
use streamflow::kernel::ClosureSink;
use streamflow::placement::{affinity_disabled_by_env, BudgetPolicy, SyntheticLoad};
use streamflow::prelude::*;
use streamflow::queue::{instrumented, StreamConfig};
use streamflow::testutil::ScriptedStage;
use streamflow::workload::{Item, PacedProducer};

/// The shared scriptable stage, parameterized for these tests: an
/// overload-ready stage whose every lane serves `tc_per_lane` items per
/// 10 ms probe, no cooldown.
fn scripted(replicas: usize, max: usize, tc_per_lane: u64) -> Arc<ScriptedStage> {
    ScriptedStage::new(
        "scripted",
        replicas,
        ElasticPolicy { max_replicas: max, cooldown_ticks: 0, ..Default::default() },
        tc_per_lane,
    )
}

/// Overloaded stage + controller with a host-aware budget over a
/// pretended 8-cpu host, fed by the given synthetic load source.
fn host_aware_harness(
    load: &Arc<SyntheticLoad>,
) -> (Arc<ScriptedStage>, Arc<streamflow::queue::SpscQueue<u64>>, ElasticController) {
    let stage = scripted(1, 8, 10); // μ = 1k/s at 10 ms ticks
    let (upq, handle) = instrumented::<u64>(&StreamConfig::default().with_capacity(1 << 20));
    let (fwd_tx, _fwd_rx) = std::sync::mpsc::channel();
    let ctl = ElasticController::new(
        ElasticConfig {
            buffer_advice: false,
            ewma_alpha: 1.0,
            worker_budget: BudgetPolicy::HostAware { headroom: 0.0, floor: 1, ceil: 8 },
            load_source: Some(SyntheticLoad::handle_of(&load)),
            host_cpus_override: Some(8),
            ..Default::default()
        },
        vec![StageBinding {
            stage: stage.clone(),
            upstream: Some(StreamBinding {
                id: StreamId(0),
                label: "src -> scripted".into(),
                handle,
            }),
            downstream: None,
        }],
        vec![],
        fwd_tx,
        Arc::new(AtomicBool::new(false)),
    );
    (stage, upq, ctl)
}

#[test]
fn synthetic_host_load_shrinks_budget_within_one_epoch_and_restores() {
    let load = SyntheticLoad::new(0.0);
    let (stage, upq, mut ctl) = host_aware_harness(&load);
    let feed = |n: u64| {
        for i in 0..n {
            let _ = upq.try_push(i);
        }
    };
    // Idle host, λ = 8k/s vs μ = 1k/s per replica: the stage claims all 8.
    for _ in 0..4 {
        feed(80);
        ctl.step(0.010);
    }
    assert_eq!(stage.replicas(), 8, "idle host must allow the full claim");

    // An external tenant takes 3/4 of the machine: the very next control
    // epoch must see budget 8 → 2 and trim the coordinated total to it.
    load.set_external(0.75);
    feed(80);
    ctl.step(0.010);
    assert_eq!(
        stage.replicas(),
        2,
        "replica total must shrink within ONE control epoch of host load"
    );

    // The tenant leaves: the budget and the claim recover.
    load.set_external(0.0);
    feed(80);
    ctl.step(0.010);
    assert_eq!(stage.replicas(), 8, "cleared host must restore the fan-out");

    let report = ctl.into_report();
    let budgets: Vec<usize> = report.budget_timeline.iter().map(|&(_, b)| b).collect();
    assert_eq!(budgets, vec![8, 2, 8], "audited budget path: {:?}", report.budget_timeline);
    assert!(report.notes.is_empty(), "healthy telemetry must not be annotated");
    let downs = report
        .events
        .iter()
        .filter(|e| matches!(e.action, streamflow::elastic::ElasticAction::ScaleDown { .. }))
        .count();
    assert!(downs >= 1, "the trim must be audited: {:?}", report.events);
}

#[test]
fn budget_timeline_lands_in_the_run_report_end_to_end() {
    // A real scheduled run under a host-aware budget with fixed 50%
    // synthetic load over a pretended 8-cpu host: the effective budget
    // (4) must be visible in RunReport::budget_timeline and the
    // human-readable scaling timeline.
    let load = SyntheticLoad::new(0.5);
    struct NoopWorker;
    impl Replicable for NoopWorker {
        type In = Item;
        type Out = Item;
        fn process(&mut self, v: Item) -> Item {
            v
        }
    }
    let seen = Arc::new(std::sync::atomic::AtomicU64::new(0));
    let s2 = seen.clone();
    let items = 2_000u64;
    let flow = Flow::new("budget-e2e")
        .stream_defaults(StreamConfig::default().with_capacity(1024))
        .source::<Item>(Box::new(PacedProducer::from_rate_items_per_sec(
            "prod", 20_000.0, items,
        )))
        .elastic(
            "work",
            ElasticStageConfig {
                policy: ElasticPolicy { max_replicas: 4, ..Default::default() },
                initial_replicas: 1,
                lane_capacity: 256,
                ..Default::default()
            },
            |_| NoopWorker,
        )
        .unwrap()
        .sink(Box::new(ClosureSink::new("snk", move |_: Item| {
            s2.fetch_add(1, Ordering::Relaxed);
        })))
        .unwrap();
    let report = Session::run_flow(
        flow,
        RunOptions::default().with_elastic(ElasticConfig {
            tick: Duration::from_millis(5),
            buffer_advice: false,
            worker_budget: BudgetPolicy::HostAware { headroom: 0.0, floor: 1, ceil: 8 },
            load_source: Some(SyntheticLoad::handle_of(&load)),
            host_cpus_override: Some(8),
            ..Default::default()
        }),
    )
    .unwrap();
    assert_eq!(seen.load(Ordering::Relaxed), items, "item loss");
    assert!(
        !report.budget_timeline.is_empty(),
        "host-aware run must audit its budget in the report"
    );
    assert!(
        report.budget_timeline.iter().all(|&(_, b)| b == 4),
        "constant 50% load over 8 cpus is a constant budget of 4: {:?}",
        report.budget_timeline
    );
    assert!(
        report.scaling_timeline().iter().any(|l| l.contains("worker budget")),
        "budget must appear in the human-readable timeline: {:?}",
        report.scaling_timeline()
    );
}

#[test]
fn host_aware_budget_degrades_to_annotated_ceiling_without_telemetry() {
    struct Dead;
    impl streamflow::placement::LoadSource for Dead {
        fn host_ticks(&self) -> Option<(u64, u64)> {
            None
        }
    }
    let stage = scripted(1, 8, 10);
    let (upq, handle) = instrumented::<u64>(&StreamConfig::default().with_capacity(1 << 20));
    let (fwd_tx, _fwd_rx) = std::sync::mpsc::channel();
    let mut ctl = ElasticController::new(
        ElasticConfig {
            buffer_advice: false,
            ewma_alpha: 1.0,
            worker_budget: BudgetPolicy::HostAware { headroom: 0.0, floor: 1, ceil: 6 },
            load_source: Some(streamflow::placement::LoadSourceHandle::new(Arc::new(Dead))),
            host_cpus_override: Some(8),
            ..Default::default()
        },
        vec![StageBinding {
            stage: stage.clone(),
            upstream: Some(StreamBinding {
                id: StreamId(0),
                label: "src -> scripted".into(),
                handle,
            }),
            downstream: None,
        }],
        vec![],
        fwd_tx,
        Arc::new(AtomicBool::new(false)),
    );
    for _ in 0..5 {
        for i in 0..80u64 {
            let _ = upq.try_push(i);
        }
        ctl.step(0.010);
    }
    assert_eq!(stage.replicas(), 6, "blind host-aware budget holds at the ceiling");
    let report = ctl.into_report();
    assert_eq!(report.notes.len(), 1, "degradation annotated exactly once: {:?}", report.notes);
    assert!(report.notes[0].contains("unavailable"));
}

// ------------------------------------------------------------ pinning --

/// Run a small elastic pipeline under `PlacementPolicy::Pack` and hand
/// back (delivered count ok, report).
fn run_pinned_pipeline() -> RunReport {
    struct AddOne;
    impl Replicable for AddOne {
        type In = u64;
        type Out = u64;
        fn process(&mut self, v: u64) -> u64 {
            v + 1
        }
    }
    let out = Arc::new(Mutex::new(Vec::new()));
    let o2 = out.clone();
    let mut i = 0u64;
    let items = 10_000u64;
    let flow = Flow::new("pinned")
        .stream_defaults(StreamConfig::default().with_capacity(1024))
        .source::<u64>(Box::new(streamflow::kernel::ClosureSource::new("src", move || {
            i += 1;
            (i <= items).then_some(i)
        })))
        .elastic(
            "work",
            ElasticStageConfig {
                policy: ElasticPolicy::pinned(2),
                initial_replicas: 2,
                lane_capacity: 128,
                ..Default::default()
            },
            |_| AddOne,
        )
        .unwrap()
        .sink(Box::new(ClosureSink::new("snk", move |v: u64| o2.lock().unwrap().push(v))))
        .unwrap();
    let report = Session::run_flow(
        flow,
        RunOptions::default().with_placement(PlacementPolicy::Pack),
    )
    .unwrap();
    let v = out.lock().unwrap();
    assert_eq!(v.len(), items as usize, "pinning must not lose items");
    assert!(
        v.iter().enumerate().all(|(idx, &x)| x == idx as u64 + 2),
        "pinning must not reorder items"
    );
    report
}

#[test]
fn pack_placement_pins_or_degrades_to_annotated_noop() {
    let report = run_pinned_pipeline();
    assert_eq!(report.placement.assignments.len(), 1, "one assignment per stage");
    let a = &report.placement.assignments[0];
    assert_eq!(a.target, "work");
    assert!(!a.cpus.is_empty(), "a stage always gets a cpu set (shared if scarce)");
    // Split + merge + 2 workers = at least 4 pin attempts, each either
    // applied or refused-and-annotated — never silently dropped.
    assert!(
        a.pinned_threads + a.denied_threads >= 4,
        "every stage thread gets a pin attempt: {a:?}"
    );
    if affinity_disabled_by_env() {
        // The CI fallback lane (SF_NO_AFFINITY=1): affinity must be an
        // explicit no-op with the reason recorded.
        assert_eq!(a.pinned_threads, 0, "denied host must pin nothing: {a:?}");
        assert!(report.placement.is_noop());
        assert!(
            a.note.as_deref().unwrap_or("").contains("SF_NO_AFFINITY"),
            "refusal reason must be recorded: {a:?}"
        );
    } else if a.denied_threads > 0 {
        assert!(a.note.is_some(), "denials must carry a reason: {a:?}");
    }
    // First-touch NUMA audit: either the stage resolved to one node (the
    // note names it), its cpu set straddles nodes, or node discovery
    // degraded — every case leaves a written trace, never silence.
    let numa_audited = a.numa_node.is_some()
        || report.placement.notes.iter().any(|n| {
            n.contains("numa fallback")
                || n.contains("first-touch")
                || n.contains("spans numa nodes")
                || n.contains("cpu topology unreadable")
        });
    assert!(numa_audited, "numa placement must be audited: {:?}", report.placement.notes);
}

#[test]
fn pack_placement_without_stages_is_an_annotated_noop() {
    let mut i = 0u64;
    let flow = Flow::new("plain")
        .source::<u64>(Box::new(streamflow::kernel::ClosureSource::new("src", move || {
            i += 1;
            (i <= 100).then_some(i)
        })))
        .sink(Box::new(ClosureSink::new("snk", |_: u64| {})))
        .unwrap();
    let report = Session::run_flow(
        flow,
        RunOptions::default().with_placement(PlacementPolicy::Pack),
    )
    .unwrap();
    assert!(report.placement.assignments.is_empty());
    assert!(
        report.placement.notes.iter().any(|n| n.contains("no replicable stages")),
        "the no-op must be annotated: {:?}",
        report.placement.notes
    );
}

#[test]
fn disabled_placement_reports_nothing() {
    let mut i = 0u64;
    let flow = Flow::new("plain")
        .source::<u64>(Box::new(streamflow::kernel::ClosureSource::new("src", move || {
            i += 1;
            (i <= 100).then_some(i)
        })))
        .sink(Box::new(ClosureSink::new("snk", |_: u64| {})))
        .unwrap();
    let report = Session::run_flow(flow, RunOptions::default()).unwrap();
    assert!(report.placement.assignments.is_empty());
    assert!(report.placement.notes.is_empty());
    assert!(report.budget_timeline.is_empty());
}
