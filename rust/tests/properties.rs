//! Property-based tests over the coordinator's core invariants, using the
//! in-crate `testutil` harness (proptest is not vendored in this image).

use streamflow::estimator::filters::{gauss_filter, log_filter, GAUSS_TAPS};
use streamflow::estimator::{EstimatorConfig, FeedOutcome, NativeBackend, ServiceRateEstimator};
use streamflow::queue::{PopResult, SpscQueue};
use streamflow::rng::Xoshiro256pp;
use streamflow::stats::{percentile, Moments, Welford};
use streamflow::testutil::{check, check_with, gen_vec_f64, shrink_vec_f64, PropConfig};

fn cfg(cases: u32, seed: u64) -> PropConfig {
    PropConfig { cases, seed, max_shrink: 200 }
}

// ---------------------------------------------------------------- queue --

#[test]
fn prop_queue_fifo_no_loss_any_interleaving() {
    // For random push/pop interleavings on one thread, the queue is an
    // exact FIFO: popped sequence is a prefix-respecting subsequence.
    check(
        cfg(64, 1),
        |rng| {
            let ops: Vec<bool> = (0..rng.next_bounded(512) + 8)
                .map(|_| rng.next_f64() < 0.55)
                .collect();
            let cap = 1 + rng.next_bounded(32) as usize;
            (ops, cap)
        },
        |(ops, cap)| {
            let q = SpscQueue::new(*cap, 8);
            let mut pushed = 0u64;
            let mut expect_next = 0u64;
            for &is_push in ops {
                if is_push {
                    if q.try_push(pushed).is_ok() {
                        pushed += 1;
                    }
                } else if let PopResult::Item(v) = q.try_pop() {
                    if v != expect_next {
                        return false;
                    }
                    expect_next += 1;
                }
            }
            // Drain the rest.
            while let PopResult::Item(v) = q.try_pop() {
                if v != expect_next {
                    return false;
                }
                expect_next += 1;
            }
            expect_next == pushed && q.len() == 0
        },
    );
}

#[test]
fn prop_queue_len_never_exceeds_capacity() {
    check(
        cfg(48, 2),
        |rng| {
            let cap = 1 + rng.next_bounded(64) as usize;
            let ops: Vec<bool> =
                (0..rng.next_bounded(256) + 1).map(|_| rng.next_f64() < 0.7).collect();
            (cap, ops)
        },
        |(cap, ops)| {
            let q = SpscQueue::new(*cap, 8);
            for &is_push in ops {
                if is_push {
                    let _ = q.try_push(0u64);
                } else {
                    let _ = q.try_pop();
                }
                if q.len() > *cap {
                    return false;
                }
            }
            true
        },
    );
}

#[test]
fn prop_queue_tc_accounting_exact() {
    // tc counters summed over arbitrary sampling points equal the true
    // push/pop counts.
    check(
        cfg(48, 3),
        |rng| {
            (0..rng.next_bounded(300) + 10)
                .map(|_| rng.next_bounded(3)) // 0 = push, 1 = pop, 2 = sample
                .collect::<Vec<u32>>()
        },
        |ops| {
            let q = SpscQueue::new(1024, 8);
            let (mut pushes, mut pops) = (0u64, 0u64);
            let (mut tc_tail_sum, mut tc_head_sum) = (0u64, 0u64);
            for &op in ops {
                match op {
                    0 => {
                        if q.try_push(0u64).is_ok() {
                            pushes += 1;
                        }
                    }
                    1 => {
                        if let PopResult::Item(_) = q.try_pop() {
                            pops += 1;
                        }
                    }
                    _ => {
                        let s = q.counters().sample();
                        tc_tail_sum += s.tc_tail;
                        tc_head_sum += s.tc_head;
                    }
                }
            }
            let s = q.counters().sample();
            tc_tail_sum += s.tc_tail;
            tc_head_sum += s.tc_head;
            tc_tail_sum == pushes && tc_head_sum == pops
        },
    );
}

// ------------------------------------------------------------- filters --

#[test]
fn prop_gauss_filter_bounds_and_width() {
    // Filter output is bounded by (min, max)·Σtaps and exactly 4 narrower.
    check_with(
        cfg(128, 4),
        |rng| gen_vec_f64(rng, 5, 128, 0.0, 1.0e6),
        |v| shrink_vec_f64(v),
        |v| {
            let out = gauss_filter(v);
            if out.len() != v.len() - 4 {
                return false;
            }
            let taps_sum: f64 = GAUSS_TAPS.iter().sum();
            let lo = v.iter().cloned().fold(f64::INFINITY, f64::min) * taps_sum;
            let hi = v.iter().cloned().fold(0.0f64, f64::max) * taps_sum;
            out.iter().all(|&x| x >= lo - 1e-6 && x <= hi + 1e-6)
        },
    );
}

#[test]
fn prop_filters_are_linear() {
    check(
        cfg(64, 5),
        |rng| {
            let n = 5 + rng.next_bounded(60) as usize;
            let a: Vec<f64> = (0..n).map(|_| rng.uniform(-100.0, 100.0)).collect();
            let b: Vec<f64> = (0..n).map(|_| rng.uniform(-100.0, 100.0)).collect();
            let (s, t) = (rng.uniform(-3.0, 3.0), rng.uniform(-3.0, 3.0));
            (a, b, s, t)
        },
        |(a, b, s, t)| {
            let combo: Vec<f64> =
                a.iter().zip(b).map(|(&x, &y)| s * x + t * y).collect();
            for (filter, tol) in [
                (gauss_filter as fn(&[f64]) -> Vec<f64>, 1e-7),
                (log_filter as fn(&[f64]) -> Vec<f64>, 1e-6),
            ] {
                let lhs = filter(&combo);
                let fa = filter(a);
                let fb = filter(b);
                for i in 0..lhs.len() {
                    if (lhs[i] - (s * fa[i] + t * fb[i])).abs() > tol {
                        return false;
                    }
                }
            }
            true
        },
    );
}

#[test]
fn prop_filter_shift_invariance() {
    // Shifting the input by k shifts the output by k (valid-mode conv).
    check(
        cfg(48, 6),
        |rng| {
            let n = 16 + rng.next_bounded(48) as usize;
            let v: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, 100.0)).collect();
            let k = 1 + rng.next_bounded(8) as usize;
            (v, k)
        },
        |(v, k)| {
            if v.len() < k + 10 {
                return true;
            }
            let full = gauss_filter(v);
            let shifted = gauss_filter(&v[*k..]);
            shifted
                .iter()
                .zip(full[*k..].iter())
                .all(|(a, b)| (a - b).abs() < 1e-9)
        },
    );
}

// --------------------------------------------------------------- stats --

#[test]
fn prop_welford_matches_two_pass() {
    check_with(
        cfg(96, 7),
        |rng| gen_vec_f64(rng, 2, 200, -1.0e4, 1.0e4),
        |v| shrink_vec_f64(v),
        |v| {
            let mut w = Welford::new();
            v.iter().for_each(|&x| w.update(x));
            let n = v.len() as f64;
            let mean = v.iter().sum::<f64>() / n;
            let var = v.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1.0);
            (w.mean() - mean).abs() < 1e-6 && (w.variance() - var).abs() < 1e-4 * var.max(1.0)
        },
    );
}

#[test]
fn prop_welford_merge_any_split() {
    check(
        cfg(64, 8),
        |rng| {
            let v = gen_vec_f64(rng, 4, 120, -100.0, 100.0);
            let split = 1 + rng.next_bounded(v.len() as u32 - 2) as usize;
            (v, split)
        },
        |(v, split)| {
            let mut all = Welford::new();
            v.iter().for_each(|&x| all.update(x));
            let (mut a, mut b) = (Welford::new(), Welford::new());
            v[..*split].iter().for_each(|&x| a.update(x));
            v[*split..].iter().for_each(|&x| b.update(x));
            let m = a.merge(&b);
            (m.mean() - all.mean()).abs() < 1e-9
                && (m.variance() - all.variance()).abs() < 1e-6 * all.variance().max(1.0)
        },
    );
}

#[test]
fn prop_moments_merge_any_split() {
    check(
        cfg(48, 9),
        |rng| {
            let v = gen_vec_f64(rng, 8, 150, 0.0, 50.0);
            let split = 2 + rng.next_bounded(v.len() as u32 - 4) as usize;
            (v, split)
        },
        |(v, split)| {
            let mut all = Moments::new();
            v.iter().for_each(|&x| all.update(x));
            let (mut a, mut b) = (Moments::new(), Moments::new());
            v[..*split].iter().for_each(|&x| a.update(x));
            v[*split..].iter().for_each(|&x| b.update(x));
            let m = a.merge(&b);
            (m.skewness() - all.skewness()).abs() < 1e-6
                && (m.kurtosis_excess() - all.kurtosis_excess()).abs() < 1e-5
        },
    );
}

#[test]
fn prop_percentile_within_minmax_and_monotone() {
    check(
        cfg(64, 10),
        |rng| gen_vec_f64(rng, 1, 100, -1000.0, 1000.0),
        |v| {
            let p50 = percentile(v, 50.0);
            let p95 = percentile(v, 95.0);
            let lo = v.iter().cloned().fold(f64::INFINITY, f64::min);
            let hi = v.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            p50 >= lo && p95 <= hi && p50 <= p95
        },
    );
}

// ----------------------------------------------------------- estimator --

#[test]
fn prop_estimator_q_at_least_mu() {
    // Eq. 3: q = μ + zσ with z > 0 and σ ≥ 0 ⇒ q ≥ μ, for any window.
    check_with(
        cfg(64, 11),
        |rng| gen_vec_f64(rng, 10, 64, 0.0, 1.0e5),
        |v| shrink_vec_f64(v),
        |v| {
            use streamflow::estimator::MomentsBackend;
            let mut b = NativeBackend::new();
            match b.moments(v, 1.64485) {
                Ok((mu, sigma, q)) => sigma >= 0.0 && q >= mu - 1e-9,
                Err(_) => v.len() < 6, // only tiny windows may error
            }
        },
    );
}

#[test]
fn prop_estimator_deterministic_replay() {
    // Same sample stream ⇒ identical outcomes (the estimator is pure).
    check(
        cfg(24, 12),
        |rng| gen_vec_f64(rng, 100, 400, 1.0, 100.0),
        |v| {
            let run = |xs: &[f64]| {
                let cfg =
                    EstimatorConfig { rel_tol: Some(1e-3), min_q_updates: 8, ..Default::default() };
                let mut e = ServiceRateEstimator::new(cfg, NativeBackend::new()).unwrap();
                let mut log = Vec::new();
                for (i, &x) in xs.iter().enumerate() {
                    match e.feed(x, 1000, 8, i as u64).unwrap() {
                        FeedOutcome::Converged(r) => log.push((i, r.q_bar)),
                        FeedOutcome::Updated { .. } | FeedOutcome::Accumulating => {}
                    }
                }
                log
            };
            run(v) == run(v)
        },
    );
}

#[test]
fn prop_constant_stream_estimate_scales_linearly() {
    // Feeding c·x converges to c·(estimate of x) — rate math is linear.
    check(
        cfg(16, 13),
        |rng| (rng.uniform(1.0, 100.0), rng.uniform(1.5, 4.0)),
        |&(base, scale)| {
            let converge = |c: f64| -> f64 {
                let cfg =
                    EstimatorConfig { rel_tol: Some(1e-3), min_q_updates: 8, ..Default::default() };
                let mut e = ServiceRateEstimator::new(cfg, NativeBackend::new()).unwrap();
                for i in 0..100_000u64 {
                    if let FeedOutcome::Converged(r) = e.feed(c, 1000, 8, i).unwrap() {
                        return r.q_bar;
                    }
                }
                f64::NAN
            };
            let a = converge(base);
            let b = converge(base * scale);
            (b / a - scale).abs() < 1e-6
        },
    );
}

// ---------------------------------------------------------------- json --

#[test]
fn prop_json_roundtrip() {
    use streamflow::config::json::Json;
    fn gen_json(rng: &mut Xoshiro256pp, depth: u32) -> Json {
        match rng.next_bounded(if depth > 2 { 4 } else { 6 }) {
            0 => Json::Null,
            1 => Json::Bool(rng.next_f64() < 0.5),
            2 => Json::Num((rng.uniform(-1e6, 1e6) * 100.0).round() / 100.0),
            3 => Json::Str(format!("s{}~\"\\{}", rng.next_bounded(100), rng.next_bounded(10))),
            4 => Json::Arr((0..rng.next_bounded(4)).map(|_| gen_json(rng, depth + 1)).collect()),
            _ => Json::Obj(
                (0..rng.next_bounded(4))
                    .map(|i| (format!("k{i}"), gen_json(rng, depth + 1)))
                    .collect(),
            ),
        }
    }
    check(
        cfg(128, 14),
        |rng| gen_json(rng, 0),
        |j| match Json::parse(&j.to_string()) {
            Ok(back) => back == *j,
            Err(_) => false,
        },
    );
}

// ------------------------------------------------------------ queueing --

#[test]
fn prop_mm1_probabilities_in_unit_interval() {
    use streamflow::queueing::mm1;
    check(
        cfg(128, 15),
        |rng| {
            (
                rng.uniform(1e-7, 1e-2),       // T seconds
                rng.uniform(0.0, 1.0),         // rho
                rng.uniform(1.0e3, 1.0e7),     // mu items/s
                rng.next_bounded(100_000) as u64 + 1, // C
            )
        },
        |&(t, rho, mu, c)| {
            let pr = mm1::pr_nonblocking_read(t, rho, mu);
            let pw = mm1::pr_nonblocking_write(t, c, rho, mu);
            (0.0..=1.0).contains(&pr) && (0.0..=1.0).contains(&pw)
        },
    );
}

#[test]
fn prop_blocking_probability_monotone_in_capacity() {
    use streamflow::queueing::mm1;
    check(
        cfg(64, 16),
        |rng| (rng.uniform(0.05, 0.999), rng.next_bounded(60) as u64 + 1),
        |&(rho, c)| {
            mm1::blocking_probability(rho, c) >= mm1::blocking_probability(rho, c + 1) - 1e-12
        },
    );
}

// ---------------------------------------------------------------- pacer --

#[test]
fn prop_pacer_never_bursts_under_preemption_gaps() {
    // The shared no-catch-up deadline rule: under any schedule of
    // on-time waits and preemption stalls (including deadlines already
    // far in the past after a long park), consecutive deadlines are
    // never closer than one full step — a preempted server did no work,
    // so no compensating burst is ever allowed — and no deadline is ever
    // scheduled sooner than one step from now.
    use streamflow::workload::Pacer;
    check(
        cfg(128, 9),
        |rng| {
            let step = 1 + rng.next_bounded(10_000) as u64;
            let events: Vec<(bool, u64)> = (0..rng.next_bounded(200) + 20)
                .map(|_| {
                    (rng.next_f64() < 0.25, rng.next_bounded(50 * step as u32) as u64)
                })
                .collect();
            (step, events)
        },
        |(step, events)| {
            let step = *step;
            let mut p = Pacer::default();
            let mut now = 0u64;
            let mut prev: Option<u64> = None;
            for &(stall, jitter) in events {
                let d = p.next_deadline(now, step);
                if d < now + step {
                    return false; // scheduled into the past: burst
                }
                if let Some(pd) = prev {
                    if d < pd + step {
                        return false; // deadlines closer than one step
                    }
                }
                prev = Some(d);
                // Advance the clock: an on-time wait lands exactly on the
                // deadline; a preemption stall overshoots it arbitrarily.
                now = if stall { d.saturating_add(step + jitter) } else { d };
            }
            true
        },
    );
}

#[test]
fn pacer_long_run_rate_is_exact_then_resets_after_long_park() {
    use streamflow::workload::Pacer;
    let step = 1_000u64;
    let mut p = Pacer::default();
    // A server that keeps up (each next_deadline call lands before the
    // previous deadline expires, with jittery call times): deadlines
    // advance by exactly one step per item — the long-run rate is exact,
    // uncorrupted by the jitter.
    let mut now = 500u64;
    let d0 = p.next_deadline(now, step);
    for k in 1..100u64 {
        now = d0 + (k - 1) * step - 137; // called 137 ns before the deadline
        let d = p.next_deadline(now, step);
        assert_eq!(d, d0 + k * step, "a keeping-up server steps from the prior deadline");
    }
    // A deadline already far in the past (long park / descheduling): the
    // next deadline steps from *now* — the lost time is forfeited, not
    // compensated with a burst.
    let far = d0 + 1_000 * step;
    let d = p.next_deadline(far, step);
    assert_eq!(d, far + step, "no catch-up after a long stall");
    // And the rule re-anchors: the following item is one step later.
    assert_eq!(p.next_deadline(d, step), far + 2 * step);
}
