//! Resize-under-burst acceptance (ISSUE 8 satellite): a paced producer
//! running at 2× the consumer's service rate with the [`BufferAdvisor`]
//! live on the stream.
//!
//! The contiguous ring stands in for "provisioned once at its maximum":
//! the advisor may only gate admission inside that allocation
//! (`max_capacity` = the provisioned slots), so the burst stalls the
//! producer. The segmented backend makes growth allocation-cheap, so the
//! same advisor is allowed to follow the burst — producer
//! `write_blocked_ns` must drop. Conservation
//! `pushes == pops + occupancy` is asserted at every mid-run scrape on
//! both backends.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use streamflow::classify::DistributionClass;
use streamflow::control::{BufferAdvisor, StreamRates};
use streamflow::queue::{build, QueueBackend, StreamConfig};
use streamflow::topology::StreamId;

/// Items pushed end to end. Small enough for CI, large enough that the
/// ring-capped run (256 slots, consumer at half the producer's pace)
/// must block the producer for most of the run.
const TOTAL: u64 = 4096;
/// Provisioned ring capacity — the advisor's ceiling on the ring run.
const PROVISIONED: usize = 256;
/// Producer burst granularity (items between pacing sleeps).
const PROD_BATCH: u64 = 64;

/// One burst run on `backend`, with the advisor live and clamped at
/// `advisor_max`. Returns the producer's total `write_blocked_ns`.
fn burst_run(backend: QueueBackend, advisor_max: usize) -> u64 {
    let cfg = StreamConfig::default().with_capacity(PROVISIONED).with_backend(backend);
    let (q, handle) = build::<u64>(&cfg);
    let done = Arc::new(AtomicBool::new(false));

    // Advisor live: a monitor thread scrapes every 500 µs, re-derives
    // λ/μ from the counter deltas, and applies the analytic sizing under
    // the controller's 25% relative-change gate.
    let advisor = BufferAdvisor { max_capacity: advisor_max, ..Default::default() };
    let mon_handle = handle.clone();
    let mon_done = done.clone();
    let monitor = thread::spawn(move || {
        let c = mon_handle.counters();
        let (mut last_pushes, mut last_pops) = (0u64, 0u64);
        let mut last_t = Instant::now();
        let mut scrapes = 0u32;
        while !mon_done.load(Ordering::Acquire) {
            thread::sleep(Duration::from_micros(500));
            // Conservation at every mid-run scrape: reading pops (head)
            // before pushes (tail) makes the difference the occupancy at
            // some instant in between — it may never go negative.
            let pops = c.total_pops();
            let pushes = c.total_pushes();
            assert!(
                pushes >= pops,
                "conservation violated mid-run: pushes {pushes} < pops {pops}"
            );
            let occupancy = pushes - pops;
            assert_eq!(pushes, pops + occupancy);
            scrapes += 1;
            let dt = last_t.elapsed().as_secs_f64().max(1e-6);
            last_t = Instant::now();
            let lambda = (pushes - last_pushes) as f64 / dt;
            let mu = (pops - last_pops) as f64 / dt;
            (last_pushes, last_pops) = (pushes, pops);
            if lambda <= 0.0 || mu <= 0.0 {
                continue;
            }
            let rates = StreamRates { lambda_items: Some(lambda), mu_items: Some(mu) };
            let Some(advice) = advisor.advise(StreamId(0), rates, DistributionClass::Unknown)
            else {
                continue;
            };
            let cur = mon_handle.capacity();
            if cur > 0 && advice.capacity.abs_diff(cur) as f64 / cur as f64 >= 0.25 {
                mon_handle.set_capacity(advice.capacity);
            }
        }
        scrapes
    });

    // Paced producer: bursts of PROD_BATCH with blocking pushes, then a
    // 250 µs breather — an offered load of ~2× the consumer's rate.
    let prod_q = q.clone();
    let producer = thread::spawn(move || {
        for i in 0..TOTAL {
            prod_q.push(i).expect("stream closed under the producer");
            if (i + 1) % PROD_BATCH == 0 {
                thread::sleep(Duration::from_micros(250));
            }
        }
        prod_q.close();
    });

    // Paced consumer: up to PROD_BATCH items per 500 µs — half the
    // producer's offered rate — verifying FIFO order end to end.
    let cons_q = q.clone();
    let consumer = thread::spawn(move || {
        let mut expect = 0u64;
        let mut buf = Vec::with_capacity(PROD_BATCH as usize);
        loop {
            let n = cons_q.pop_batch(&mut buf, PROD_BATCH as usize);
            for v in buf.drain(..) {
                assert_eq!(v, expect, "items lost or reordered under resize");
                expect += 1;
            }
            if n == 0 {
                if cons_q.is_finished() {
                    break;
                }
                thread::yield_now();
                continue;
            }
            thread::sleep(Duration::from_micros(500));
        }
        expect
    });

    producer.join().unwrap();
    assert_eq!(consumer.join().unwrap(), TOTAL);
    done.store(true, Ordering::Release);
    let scrapes = monitor.join().unwrap();
    assert!(scrapes > 0, "the advisor never scraped the stream");

    // End-state conservation: everything pushed was popped.
    let c = q.counters();
    assert_eq!(c.total_pushes(), TOTAL);
    assert_eq!(c.total_pops(), TOTAL);
    assert_eq!(q.len(), 0);
    match backend {
        QueueBackend::Ring => {
            assert_eq!(c.segments(), 0, "ring must not report segments");
        }
        QueueBackend::Segmented => {
            assert!(c.segments() >= 1, "segmented stream lost its tail segment");
            assert!(c.segment_allocs() >= 1, "segment allocations must be audited");
        }
    }
    c.total_write_blocked_ns()
}

#[test]
fn resize_under_burst_segmented_blocks_less_than_ring() {
    // Ring: provisioned at 256 slots; the live advisor can only gate
    // admission within that allocation, so the 2× burst stalls the
    // producer for roughly the consumer's half of the run.
    let ring_blocked = burst_run(QueueBackend::Ring, PROVISIONED);
    // Segmented: identical workload and advisor, but growth is
    // allocation-cheap so the sizing may follow the burst.
    let seg_blocked = burst_run(QueueBackend::Segmented, 1 << 16);
    assert!(
        ring_blocked > 0,
        "ring-with-advisor must stall the producer under a 2x burst"
    );
    assert!(
        seg_blocked < ring_blocked,
        "segmented backend must cut producer write_blocked_ns: \
         segmented {seg_blocked} ns vs ring {ring_blocked} ns"
    );
}

#[test]
fn conservation_holds_through_shrink_below_occupancy() {
    // Both backends: fill half, shrink the admission cap below the
    // occupancy, and scrape the conservation identity while a consumer
    // drains — the deferred shrink must never lose an item.
    for backend in [QueueBackend::Ring, QueueBackend::Segmented] {
        let cfg = StreamConfig::default().with_capacity(1024).with_backend(backend);
        let (q, handle) = build::<u64>(&cfg);
        for i in 0..512u64 {
            q.try_push(i).unwrap();
        }
        handle.set_capacity(32);
        assert_eq!(q.len(), 512, "{backend:?}: shrink dropped queued items");
        let mut expect = 0u64;
        while let streamflow::queue::PopResult::Item(v) = q.try_pop() {
            assert_eq!(v, expect);
            expect += 1;
            let pops = q.counters().total_pops();
            let pushes = q.counters().total_pushes();
            assert_eq!(pushes, pops + q.len() as u64, "{backend:?}: conservation broke mid-drain");
        }
        assert_eq!(expect, 512);
        // Admission reopened at the shrunken cap.
        assert!(q.try_push(0).is_ok());
        assert_eq!(handle.capacity(), 32);
    }
}
