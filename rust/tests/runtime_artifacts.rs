//! Integration: the AOT artifact pipeline end to end, through the same
//! `xla`-crate path the monitor uses.
//!
//! Requires the `pjrt` cargo feature (the whole file compiles away on the
//! default offline build, where `Engine::load_dir` always errors) *and*
//! `make artifacts` (skips with a notice otherwise).
#![cfg(feature = "pjrt")]

use std::path::PathBuf;

use streamflow::estimator::{MomentsBackend, NativeBackend};
use streamflow::runtime::Engine;

fn artifact_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).parent().unwrap().join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifact_dir() {
            Some(d) => d,
            None => {
                eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
                return;
            }
        }
    };
}

#[test]
fn manifest_lists_expected_artifacts() {
    let dir = require_artifacts!();
    let eng = Engine::load_dir(&dir).expect("engine");
    let names = eng.manifest().names();
    for expect in ["estimator_b1_w64", "estimator_b8_w64", "convergence_b1_w16"] {
        assert!(names.contains(&expect), "missing artifact {expect}: {names:?}");
    }
}

#[test]
fn every_artifact_compiles_and_executes() {
    let dir = require_artifacts!();
    let eng = Engine::load_dir(&dir).expect("engine");
    for name in eng.manifest().names() {
        let exec = eng.load_artifact(name).unwrap_or_else(|e| panic!("{name}: {e}"));
        let specs = exec.spec().inputs.clone();
        let bufs: Vec<Vec<f32>> = specs.iter().map(|s| vec![0.25f32; s.elements()]).collect();
        let dims: Vec<Vec<i64>> =
            specs.iter().map(|s| s.shape.iter().map(|&d| d as i64).collect()).collect();
        let inputs: Vec<(&[f32], &[i64])> =
            bufs.iter().zip(&dims).map(|(b, d)| (b.as_slice(), d.as_slice())).collect();
        let outs = exec.run_f32(&inputs).unwrap_or_else(|e| panic!("{name} exec: {e}"));
        assert_eq!(outs.len(), exec.spec().outputs.len(), "{name} output arity");
        for (o, spec) in outs.iter().zip(&exec.spec().outputs) {
            assert_eq!(o.len(), spec.elements(), "{name} output size");
            assert!(o.iter().all(|v| v.is_finite()), "{name} produced non-finite values");
        }
    }
}

#[test]
fn xla_estimator_matches_native_backend() {
    // The cross-layer parity check: Pallas moments kernel (via PJRT) vs
    // the Rust hot path, across several window shapes.
    let dir = require_artifacts!();
    let mut xla = streamflow::estimator::backend::XlaBackend::from_dir(&dir, 64)
        .expect("xla backend");
    let mut native = NativeBackend::new();
    let mut rng = streamflow::rng::Xoshiro256pp::new(0x77);
    for case in 0..25 {
        let base = rng.uniform(1.0, 5000.0);
        let spread = rng.uniform(0.0, base / 4.0);
        let window: Vec<f64> =
            (0..64).map(|_| base + rng.uniform(-spread, spread)).collect();
        let (n_mu, n_sigma, n_q) = native.moments(&window, 1.64485).unwrap();
        let (x_mu, x_sigma, x_q) = xla.moments(&window, 1.64485).unwrap();
        let rel = |a: f64, b: f64| (a - b).abs() / a.abs().max(1e-9);
        assert!(rel(n_mu, x_mu) < 2e-3, "case {case}: mu {n_mu} vs {x_mu}");
        assert!(
            (n_sigma - x_sigma).abs() / n_sigma.max(1e-3) < 2e-2,
            "case {case}: sigma {n_sigma} vs {x_sigma}"
        );
        assert!(rel(n_q, x_q) < 5e-3, "case {case}: q {n_q} vs {x_q}");
    }
}

#[test]
fn xla_convergence_filter_matches_native() {
    let dir = require_artifacts!();
    let eng = Engine::load_dir(&dir).expect("engine");
    let exec = eng.load_artifact("convergence_b1_w16").expect("artifact");
    let mut rng = streamflow::rng::Xoshiro256pp::new(0x78);
    for _ in 0..10 {
        let v: Vec<f64> = (0..16).map(|_| rng.uniform(0.0, 1e-3)).collect();
        let v32: Vec<f32> = v.iter().map(|&x| x as f32).collect();
        let outs = exec.run_f32(&[(&v32, &[1, 16])]).expect("exec");
        // outs = [filtered (14), min (1), max (1)]
        let native = streamflow::estimator::filters::log_filter(&v);
        assert_eq!(outs[0].len(), 14);
        for (g, w) in outs[0].iter().zip(&native) {
            assert!((*g as f64 - w).abs() < 1e-5, "filtered {g} vs {w}");
        }
        let (lo, hi) = (outs[1][0] as f64, outs[2][0] as f64);
        let n_lo = native.iter().cloned().fold(f64::INFINITY, f64::min);
        let n_hi = native.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        assert!((lo - n_lo).abs() < 1e-5);
        assert!((hi - n_hi).abs() < 1e-5);
    }
}

#[test]
fn dot_artifact_matches_native_matmul() {
    let dir = require_artifacts!();
    let eng = Engine::load_dir(&dir).expect("engine");
    let name = "dot_m16_k256_n256";
    if eng.manifest().get(name).is_none() {
        eprintln!("SKIP: {name} not in manifest");
        return;
    }
    let exec = eng.load_artifact(name).expect("artifact");
    let mut rng = streamflow::rng::Xoshiro256pp::new(0x79);
    let a: Vec<f32> = (0..16 * 256).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
    let b: Vec<f32> = (0..256 * 256).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
    let outs = exec.run_f32(&[(&a, &[16, 256]), (&b, &[256, 256])]).expect("exec");
    let c = &outs[0];
    // Spot-check a handful of entries against a native dot product.
    for &(i, j) in &[(0usize, 0usize), (3, 17), (15, 255), (7, 128)] {
        let mut want = 0.0f32;
        for k in 0..256 {
            want += a[i * 256 + k] * b[k * 256 + j];
        }
        let got = c[i * 256 + j];
        assert!((got - want).abs() < 1e-2, "C[{i},{j}] = {got} vs {want}");
    }
}

#[test]
fn shape_validation_rejects_mismatches() {
    let dir = require_artifacts!();
    let eng = Engine::load_dir(&dir).expect("engine");
    let exec = eng.load_artifact("estimator_b1_w64").expect("artifact");
    let bad = vec![0.0f32; 32];
    assert!(exec.run_f32(&[(&bad, &[1, 32])]).is_err(), "wrong shape must be rejected");
    let good_shape_wrong_len = vec![0.0f32; 10];
    assert!(exec.run_f32(&[(&good_shape_wrong_len, &[1, 64])]).is_err());
    assert!(exec.run_f32(&[]).is_err(), "wrong arity must be rejected");
}
