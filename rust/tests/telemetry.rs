//! The live telemetry plane, outside-in: mid-run `/metrics` scrapes obey
//! the queue conservation law and agree with the final [`RunReport`], the
//! structured event ring reproduces the legacy scaling timeline exactly,
//! overflow is audited rather than silent, the Prometheus rendering is
//! well-formed, the chrome-trace export loads as valid trace JSON, and the
//! JSONL tail captures a real elastic run line by line.

use std::io::{Read as _, Write as _};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use streamflow::config::Json;
use streamflow::elastic::{
    ElasticAction, ElasticConfig, ElasticController, ElasticEvent, ElasticStage,
    ElasticStageConfig, StageBinding, StageTrajectory, StreamBinding,
};
use streamflow::kernel::{ClosureSink, ClosureSource};
use streamflow::monitor::QueueEnd;
use streamflow::prelude::*;
use streamflow::queue::{instrumented, MonitorSample};
use streamflow::telemetry::{
    BlockEnd, ControlEvent, EventRing, MetricsRegistry, MetricsShared, TelemetryConfig,
};

// ------------------------------------------------------------- helpers --

fn http_get(addr: SocketAddr, path: &str) -> String {
    let mut s = TcpStream::connect(addr).expect("connect to metrics server");
    write!(s, "GET {path} HTTP/1.1\r\nHost: test\r\nConnection: close\r\n\r\n").unwrap();
    let mut buf = String::new();
    s.read_to_string(&mut buf).unwrap();
    buf
}

/// Value of `name{key="label"} v` (or unlabeled `name v`) in a scrape.
fn metric_value(text: &str, name: &str, label: Option<(&str, &str)>) -> Option<f64> {
    let needle = match label {
        Some((k, v)) => format!("{name}{{{k}=\"{v}\"}} "),
        None => format!("{name} "),
    };
    text.lines()
        .find_map(|l| l.strip_prefix(&needle))
        .and_then(|v| v.trim().parse().ok())
}

fn temp_path(stem: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("sf-test-{}-{stem}", std::process::id()))
}

/// A scriptable threadless stage: every lane reports `tc_per_lane`
/// service transactions per probe and no blocking.
struct ScriptedStage {
    replicas: Mutex<usize>,
    policy: ElasticPolicy,
    tc_per_lane: AtomicU64,
}

impl ScriptedStage {
    fn new(replicas: usize, policy: ElasticPolicy, tc: u64) -> Arc<Self> {
        Arc::new(ScriptedStage {
            replicas: Mutex::new(replicas),
            policy,
            tc_per_lane: AtomicU64::new(tc),
        })
    }
}

impl ElasticStage for ScriptedStage {
    fn stage_name(&self) -> &str {
        "scripted"
    }
    fn replicas(&self) -> usize {
        *self.replicas.lock().unwrap()
    }
    fn scale_to(&self, n: usize) -> usize {
        let n = self.policy.clamp(n);
        *self.replicas.lock().unwrap() = n;
        n
    }
    fn lane_probe(&self) -> Vec<MonitorSample> {
        let tc = self.tc_per_lane.load(Ordering::Relaxed);
        (0..self.replicas())
            .map(|_| MonitorSample {
                tc_head: tc,
                tc_tail: tc,
                read_blocked_ns: 0,
                write_blocked_ns: 0,
                ..Default::default()
            })
            .collect()
    }
    fn backlog(&self) -> usize {
        0
    }
    fn policy(&self) -> &ElasticPolicy {
        &self.policy
    }
    fn input_closed(&self) -> bool {
        false
    }
    fn join_workers(&self) {}
}

/// A threadless controller over one scripted stage fed through a real
/// instrumented stream: `feed` items arrive per 10 ms tick.
fn scripted_run(
    budget: BudgetPolicy,
    ring: Option<(Arc<EventRing>, Arc<MetricsShared>)>,
) -> streamflow::elastic::ControlPlaneReport {
    let policy = ElasticPolicy { max_replicas: 8, cooldown_ticks: 0, ..Default::default() };
    let stage = ScriptedStage::new(1, policy, 20);
    let (upq, handle) = instrumented::<u64>(&StreamConfig::default().with_capacity(4096));
    let (fwd_tx, _fwd_rx) = std::sync::mpsc::channel();
    let mut ctl = ElasticController::new(
        ElasticConfig {
            buffer_advice: false,
            ewma_alpha: 1.0,
            worker_budget: budget,
            ..Default::default()
        },
        vec![StageBinding {
            stage: stage.clone(),
            upstream: Some(StreamBinding {
                id: StreamId(0),
                label: "src.0 -> scripted.0".into(),
                handle,
            }),
            downstream: None,
        }],
        vec![],
        fwd_tx,
        Arc::new(AtomicBool::new(false)),
    );
    if let Some((ring, shared)) = ring {
        ctl.attach_telemetry(ring, shared);
    }
    // 80 arrivals per 10 ms tick = 8k items/s against 2k items/s per
    // replica: the coordinated advice is ceil(8000 / (0.7 * 2000)) = 6.
    for _ in 0..6 {
        for i in 0..80u64 {
            let _ = upq.try_push(i);
        }
        ctl.step(0.010);
    }
    ctl.into_report()
}

// ------------------------------------------------- conservation, live --

/// Satellite 3 (scrape half): a mid-run Prometheus scrape obeys
/// `pushes == pops + occupancy` for a quiescent stream, and the final
/// `RunReport` totals agree with what the scrape saw.
#[test]
fn live_scrape_is_conservation_exact_and_matches_final_report() {
    let items = 500u64;
    let mut i = 0u64;
    let gate = Arc::new(AtomicBool::new(false));
    let g2 = gate.clone();
    // The sink blocks inside the first item's closure until released, so
    // the stream quiesces at exactly (pushes=500, pops=1, occupancy=499).
    let flow = Flow::new("scrape")
        .stream_defaults(StreamConfig::default().with_capacity(1024))
        .source::<u64>(Box::new(ClosureSource::new("src", move || {
            i += 1;
            (i <= items).then_some(i)
        })))
        .sink(Box::new(ClosureSink::new("snk", move |_: u64| {
            while !g2.load(Ordering::Relaxed) {
                std::thread::sleep(Duration::from_millis(1));
            }
        })))
        .unwrap();

    let bound = Arc::new(OnceLock::new());
    let opts = RunOptions::default().with_telemetry(
        TelemetryConfig::serve("127.0.0.1:0").with_bound_cell(bound.clone()),
    );
    let runner = std::thread::spawn(move || Session::run_flow(flow, opts).unwrap());

    // Wait for the scheduler to publish the realized bind address.
    let deadline = Instant::now() + Duration::from_secs(10);
    let addr = loop {
        if let Some(a) = bound.get() {
            break *a;
        }
        assert!(Instant::now() < deadline, "metrics server never bound");
        std::thread::sleep(Duration::from_millis(2));
    };

    // Scrape until the source has drained and the sink sits blocked on
    // item 1 — from then on the invariant must hold exactly.
    let label = "src.0 -> snk.0";
    let mut last;
    let ok = loop {
        last = http_get(addr, "/metrics");
        let body = last.split("\r\n\r\n").nth(1).unwrap_or("").to_string();
        let pushes = metric_value(&body, "sf_stream_pushes_total", Some(("stream", label)));
        let pops = metric_value(&body, "sf_stream_pops_total", Some(("stream", label)));
        let occ = metric_value(&body, "sf_stream_occupancy", Some(("stream", label)));
        if let (Some(p), Some(q), Some(o)) = (pushes, pops, occ) {
            if p == items as f64 && q == 1.0 {
                assert_eq!(p, q + o, "conservation violated in a quiescent scrape:\n{body}");
                assert!(
                    metric_value(&body, "sf_events_dropped_total", None).is_some(),
                    "dropped-event audit metric missing:\n{body}"
                );
                assert!(body.contains("sf_build_info{version="), "{body}");
                break true;
            }
        }
        if Instant::now() >= deadline {
            break false;
        }
        std::thread::sleep(Duration::from_millis(2));
    };
    assert!(ok, "never observed the quiescent (500, 1, 499) state:\n{last}");
    assert!(last.starts_with("HTTP/1.1 200 OK"), "{last}");
    assert!(last.contains("text/plain; version=0.0.4"), "{last}");

    gate.store(true, Ordering::Relaxed);
    let report = runner.join().unwrap();
    assert_eq!(report.stream_totals[label], (items, items));
    assert_eq!(report.events_dropped, 0);
}

// --------------------------------------------- ring == legacy timeline --

/// Satellite 3 (timeline half): the legacy scaling-timeline views and a
/// reconstruction from nothing but the structured event journal (plus the
/// known initial conditions) render identical timelines.
#[test]
fn event_ring_reproduces_scaling_timeline_exactly() {
    let rep = scripted_run(BudgetPolicy::Fixed(6), None);
    assert_eq!(rep.events_dropped, 0);
    assert_eq!(rep.budget_timeline.len(), 1, "{:?}", rep.budget_timeline);
    assert_eq!(rep.budget_timeline[0].1, 6);

    let legacy = RunReport {
        elastic_events: rep.events.clone(),
        replica_trajectories: rep.trajectories.clone(),
        budget_timeline: rep.budget_timeline.clone(),
        ..Default::default()
    };

    // Rebuild the same three views purely from the journal. The baseline
    // (t0, initial replicas) is initial-conditions knowledge, not an
    // event — take it from the trajectory seed.
    let (t0, r0) = rep.trajectories[0].points[0];
    let mut traj = StageTrajectory { stage: "scripted".into(), points: vec![(t0, r0)] };
    let mut events = Vec::new();
    let mut budget = Vec::new();
    for ev in &rep.control_events {
        match ev {
            ControlEvent::Action(e) => {
                match e.action {
                    ElasticAction::ScaleUp { to, .. }
                    | ElasticAction::ScaleDown { to, .. } => {
                        if e.target == traj.stage {
                            traj.points.push((e.at_ns, to));
                        }
                    }
                    ElasticAction::Resize { .. } => {}
                }
                events.push(e.clone());
            }
            ControlEvent::Budget { at_ns, budget: b } => budget.push((*at_ns, *b)),
            _ => {}
        }
    }
    let rebuilt = RunReport {
        elastic_events: events,
        replica_trajectories: vec![traj],
        budget_timeline: budget,
        ..Default::default()
    };

    let a = legacy.scaling_timeline();
    let b = rebuilt.scaling_timeline();
    assert!(!a.is_empty());
    assert_eq!(a, b, "journal reconstruction diverged from the legacy views");
    assert!(a.iter().any(|l| l.starts_with("stage scripted: replicas 1@")), "{a:?}");
    assert!(a.iter().any(|l| l.starts_with("worker budget: 6@")), "{a:?}");

    // The journal is a superset: the 1 -> 6 scale must carry 5 lane
    // spawns, and every event survives a JSONL round-trip.
    let spawns = rep
        .control_events
        .iter()
        .filter(|e| matches!(e, ControlEvent::Lane { spawned: true, .. }))
        .count();
    assert_eq!(spawns, 5, "{:?}", rep.control_events);
    for ev in &rep.control_events {
        let line = ev.to_json().to_string();
        let back = Json::parse(&line).expect("JSONL round-trip");
        assert_eq!(back.get("at_ns").and_then(Json::as_f64), Some(ev.at_ns() as f64));
        assert!(back.get("type").and_then(Json::as_str).is_some(), "{line}");
    }
}

// ------------------------------------------------------------ overflow --

/// Satellite 6: a transport too small for one tick's burst loses events,
/// but the loss is audited in the report and in the scrape — and the
/// realized scaling still happened.
#[test]
fn ring_overflow_is_audited_in_report_and_scrape() {
    let ring = Arc::new(EventRing::new(2));
    let shared = MetricsShared::new(1);
    let rep = scripted_run(BudgetPolicy::Fixed(6), Some((ring.clone(), shared)));
    // The first tick bursts Budget + Action + 5 Lane events into 2 slots.
    assert!(rep.events_dropped > 0, "{:?}", rep.control_events);
    assert_eq!(
        rep.events_dropped + rep.control_events.len() as u64,
        ring.dropped() + ring.journal_len() as u64
    );

    let mut reg = MetricsRegistry::standalone();
    reg.set_ring(ring.clone());
    let text = reg.render();
    let dropped = metric_value(&text, "sf_events_dropped_total", None);
    assert_eq!(dropped, Some(ring.dropped() as f64), "{text}");
}

// ------------------------------------------------- exposition format --

/// Every rendered line is either a `# HELP`/`# TYPE` comment or a
/// `name[{labels}] value` sample with a parseable finite value.
#[test]
fn rendered_scrape_is_wellformed_prometheus_text() {
    let (q, h) = instrumented::<u64>(&StreamConfig::default().with_capacity(64));
    for i in 0..10u64 {
        q.try_push(i).unwrap();
    }
    for _ in 0..4 {
        let _ = q.pop();
    }
    let mut reg = MetricsRegistry::standalone();
    reg.add_stream(StreamId(7), "a.0 -> b.0", h);
    reg.set_ring(Arc::new(EventRing::new(8)));
    reg.shared().set_rate(StreamId(7), QueueEnd::Head, 123.456);
    let text = reg.render();

    assert!(!text.is_empty());
    for line in text.lines() {
        if line.starts_with("# HELP ") || line.starts_with("# TYPE ") {
            continue;
        }
        let (name_part, value) =
            line.rsplit_once(' ').unwrap_or_else(|| panic!("no value in line: {line}"));
        let v: f64 = value.parse().unwrap_or_else(|_| panic!("bad value in line: {line}"));
        assert!(v.is_finite(), "non-finite sample: {line}");
        assert!(
            name_part.starts_with("sf_"),
            "metric outside the sf_ namespace: {line}"
        );
        if let Some(open) = name_part.find('{') {
            assert!(name_part.ends_with('}'), "unterminated label set: {line}");
            assert!(
                name_part[..open].chars().all(|c| c.is_ascii_alphanumeric() || c == '_'),
                "bad metric name: {line}"
            );
        }
    }
    assert!(metric_value(&text, "sf_stream_pushes_total", Some(("stream", "a.0 -> b.0")))
        .is_some());
    let rate: Option<f64> = text
        .lines()
        .find_map(|l| l.strip_prefix("sf_stream_rate_mbps{stream=\"a.0 -> b.0\",end=\"head\"} "))
        .and_then(|v| v.trim().parse().ok());
    assert_eq!(rate, Some(123.456), "{text}");
}

// --------------------------------------------------------- chrome trace --

/// The Perfetto export is valid trace JSON: a `traceEvents` array with
/// metadata (`M`), counter (`C`), duration (`X`), and instant (`i`)
/// phases.
#[test]
fn chrome_trace_export_is_valid_trace_json() {
    let ms = 1_000_000u64; // ns
    let scale = ElasticEvent {
        at_ns: 5 * ms,
        target: "work".into(),
        action: ElasticAction::ScaleUp { from: 1, to: 3 },
        rho: 2.1,
        lambda_items: 9000.0,
        mu_items: 1500.0,
        pressure: false,
        starved_frac: 0.05,
        backpressure_frac: 0.4,
    };
    let report = RunReport {
        wall_ns: 20 * ms,
        elastic_events: vec![scale.clone()],
        replica_trajectories: vec![StageTrajectory {
            stage: "work".into(),
            points: vec![(ms, 1), (5 * ms, 3)],
        }],
        budget_timeline: vec![(2 * ms, 4)],
        control_events: vec![
            ControlEvent::Budget { at_ns: 2 * ms, budget: 4 },
            ControlEvent::Action(scale),
            ControlEvent::Lane { at_ns: 5 * ms, stage: "work".into(), lane: 1, spawned: true },
            ControlEvent::Lane { at_ns: 5 * ms, stage: "work".into(), lane: 2, spawned: true },
            ControlEvent::BlockedSpan {
                at_ns: 8 * ms,
                label: "src.0 -> work.0".into(),
                end: BlockEnd::Read,
                dur_ns: ms,
            },
            ControlEvent::RateConverged {
                at_ns: 9 * ms,
                stream: StreamId(0),
                end: QueueEnd::Head,
                mbps: 42.5,
            },
        ],
        ..Default::default()
    };

    let path = temp_path("trace.json");
    report.write_chrome_trace(&path).unwrap();
    let raw = std::fs::read_to_string(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    let doc = Json::parse(&raw).expect("trace must parse as JSON");
    assert_eq!(doc.get("displayTimeUnit").and_then(Json::as_str), Some("ms"));
    let events = match doc.get("traceEvents") {
        Some(Json::Arr(a)) => a,
        other => panic!("traceEvents missing or not an array: {other:?}"),
    };
    assert!(!events.is_empty());
    let mut phases = std::collections::BTreeSet::new();
    for ev in events {
        let ph = ev.get("ph").and_then(Json::as_str).expect("event without ph");
        phases.insert(ph.to_string());
        assert!(ev.get("pid").is_some(), "event without pid");
        if ph != "M" {
            let ts = ev.get("ts").and_then(Json::as_f64).expect("event without ts");
            assert!(ts >= 0.0, "timestamps must be re-based to >= 0");
        }
    }
    for need in ["M", "C", "X", "i"] {
        assert!(phases.contains(need), "missing phase {need}: {phases:?}");
    }
}

// ------------------------------------------------------------ JSONL e2e --

/// The JSONL tail of a real elastic run: every line parses, carries the
/// schema's required keys, and the run's budget shows up both in the tail
/// and in the report.
#[test]
fn jsonl_tail_captures_a_real_elastic_run() {
    struct Double;
    impl Replicable for Double {
        type In = u64;
        type Out = u64;
        fn process(&mut self, v: u64) -> u64 {
            v * 2
        }
    }
    let items = 1_000u64;
    let mut i = 0u64;
    let stage_cfg = ElasticStageConfig {
        policy: ElasticPolicy { max_replicas: 4, ..Default::default() },
        initial_replicas: 1,
        lane_capacity: 64,
        ..Default::default()
    };
    let flow = Flow::new("jsonl-e2e")
        .stream_defaults(StreamConfig::default().with_capacity(512))
        .source::<u64>(Box::new(ClosureSource::new("src", move || {
            i += 1;
            (i <= items).then_some(i)
        })))
        .elastic("dbl", stage_cfg, |_| Double)
        .unwrap()
        .sink(Box::new(ClosureSink::new("snk", |_: u64| {
            std::thread::sleep(Duration::from_micros(50));
        })))
        .unwrap();

    let path = temp_path("events.jsonl");
    let _ = std::fs::remove_file(&path);
    let ecfg = ElasticConfig {
        tick: Duration::from_millis(1),
        worker_budget: BudgetPolicy::Fixed(4),
        ..Default::default()
    };
    let opts = RunOptions::default()
        .with_elastic(ecfg)
        .with_telemetry(TelemetryConfig::default().with_jsonl(&path));
    let report = Session::run_flow(flow, opts).unwrap();

    assert_eq!(report.budget_timeline.len(), 1, "{:?}", report.budget_timeline);
    assert_eq!(report.budget_timeline[0].1, 4);
    assert_eq!(report.events_dropped, 0);

    let raw = std::fs::read_to_string(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    let lines: Vec<&str> = raw.lines().filter(|l| !l.trim().is_empty()).collect();
    assert!(!lines.is_empty(), "elastic run produced an empty event tail");
    let mut saw_budget = false;
    for line in &lines {
        let obj = Json::parse(line).unwrap_or_else(|e| panic!("bad JSONL line {line}: {e}"));
        assert!(obj.get("at_ns").and_then(Json::as_f64).is_some(), "{line}");
        let ty = obj.get("type").and_then(Json::as_str).unwrap_or_else(|| {
            panic!("line without type: {line}")
        });
        if ty == "budget" {
            saw_budget = true;
            assert_eq!(obj.get("budget").and_then(Json::as_f64), Some(4.0), "{line}");
        }
    }
    assert!(saw_budget, "budget event missing from the tail:\n{raw}");
    // The tail is exactly the journal the report was built from.
    assert_eq!(lines.len(), report.control_events.len());
}
